//! Minimal, API-compatible subset of the `anyhow` crate, vendored because the
//! build environment has no network access to crates.io.
//!
//! Supported surface (everything this repository uses):
//! * [`Error`] / [`Result`] with the `E = Error` default;
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros;
//! * the [`Context`] extension trait on `Result` and `Option`;
//! * `{}` (outermost message) and `{:#}` (full cause chain) formatting.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the conventional default type parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error value: an outermost message plus an optional chain of
/// underlying causes. Deliberately does **not** implement `std::error::Error`
/// so the blanket `From` below stays coherent (same trick as real anyhow).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The innermost cause (or this error if it has no causes).
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Self {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::from_std(&e)
    }
}

mod ext {
    use super::{Display, Error};

    /// Anything that can absorb a context message into an [`Error`]. The two
    /// impls are disjoint because `Error` itself never implements
    /// `std::error::Error`.
    pub trait IntoError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl IntoError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    fn fails() -> std::result::Result<(), std::io::Error> {
        Err(io_err())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            fails()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e = fails().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause().to_string(), "gone");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad {} of {}", "kind", 9);
        assert_eq!(e.to_string(), "bad kind of 9");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag must be set");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag must be set");
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), Error> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }
}

//! Stub of the `xla` (xla_extension) PJRT bindings used by `dwn::runtime`.
//!
//! The real crate links a prebuilt libxla_extension which is not present in
//! this container. This stub keeps every call site type-checking; the entry
//! point ([`PjRtClient::cpu`]) fails with a clear message, so PJRT-backed
//! paths report "backend unavailable" at runtime instead of breaking the
//! build. Tests that need PJRT also need trained artifacts and already skip
//! (or are `#[ignore]`d) when those are absent.

use std::fmt;

/// Error type mirroring `xla::Error`'s display behaviour.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT backend unavailable (built against the in-tree xla stub; \
             install xla_extension and swap the real `xla` crate in to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Host literal (stub: carries no data).
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Self {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable (stub: never constructible via public API, but the type
/// and methods must exist for call sites to type-check).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("PJRT backend unavailable"));
    }
}

//! Table II: comparison of LUT-based architectures on JSC. Our DWN rows and
//! our TreeLUT baseline are measured on the in-repo substrate; other rows
//! are the paper's published numbers (tagged `paper`).

use dwn::baselines::gbdt::{self, GbdtConfig};
use dwn::baselines::logicnets;
use dwn::baselines::published::TABLE2_PUBLISHED;
use dwn::baselines::treelut;
use dwn::config::Artifacts;
use dwn::data::Dataset;
use dwn::model::{DwnModel, Variant};
use dwn::report::{f1, int, measure, Table};
use dwn::techmap::map6;
use dwn::timing::{analyze, DelayModel};

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let mut rows: Vec<(f64, Vec<String>)> = Vec::new();

    // --- our DWN PEN+FT rows
    for name in ["lg-2400", "md-360", "sm-50", "sm-10"] {
        let Ok(model) = DwnModel::load(&artifacts.model_path(name)) else { continue };
        let r = measure(&model, Variant::PenFt).unwrap();
        rows.push((
            r.acc * 100.0,
            vec![
                format!("DWN-PEN+FT ({name}) ({}-Bit)", r.bits.unwrap()),
                "ours".into(),
                format!("{:.1}", r.acc * 100.0),
                int(r.timing.luts),
                int(r.timing.ffs),
                f1(r.timing.fmax_mhz),
                f1(r.timing.latency_ns),
                f1(r.timing.area_delay),
            ],
        ));
    }

    // --- our TreeLUT baseline (trained + generated in-repo)
    let train = Dataset::load_csv(&artifacts.dataset_path("train")).unwrap();
    let test = Dataset::load_csv(&artifacts.dataset_path("test")).unwrap();
    for (rounds, depth) in [(8usize, 3usize), (3, 2)] {
        let cfg = GbdtConfig { num_rounds: rounds, max_depth: depth, ..Default::default() };
        let model = gbdt::train(&train, 5, &cfg);
        let xt = gbdt::quantize_dataset(&test, cfg.frac_bits);
        let acc = model.accuracy(&xt, &test.y);
        let design = treelut::build_treelut(&model).unwrap();
        let nl = map6(&design.net);
        let rep = analyze(&nl, &DelayModel::default());
        rows.push((
            acc * 100.0,
            vec![
                format!("TreeLUT-ours (r{rounds} d{depth})"),
                "ours".into(),
                format!("{:.1}", acc * 100.0),
                int(rep.luts),
                int(rep.ffs),
                f1(rep.fmax_mhz),
                f1(rep.latency_ns),
                f1(rep.area_delay),
            ],
        ));
    }

    // --- our LogicNets-lite baseline (trained in JAX, enumerated to LUTs)
    for name in ["jsc-s", "jsc-m"] {
        let p = artifacts.root.join("models").join(format!("logicnets-{name}.json"));
        let Ok(model) = logicnets::LogicNetsModel::load(&p) else { continue };
        let design = logicnets::build_logicnets(&model).unwrap();
        let nl = map6(&design.net);
        let rep = analyze(&nl, &DelayModel::default());
        let acc = model.accuracy(&test, test.len());
        rows.push((
            acc * 100.0,
            vec![
                format!("LogicNets-lite ({name})"),
                "ours".into(),
                format!("{:.1}", acc * 100.0),
                int(rep.luts),
                int(rep.ffs),
                f1(rep.fmax_mhz),
                f1(rep.latency_ns),
                f1(rep.area_delay),
            ],
        ));
    }

    // --- published rows from the paper
    for p in TABLE2_PUBLISHED {
        rows.push((
            p.acc,
            vec![
                p.model.to_string(),
                "paper".into(),
                format!("{:.1}", p.acc),
                int(p.luts),
                int(p.ffs),
                f1(p.fmax_mhz),
                f1(p.latency_ns),
                f1(p.area_delay),
            ],
        ));
    }

    rows.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut t = Table::new(
        "Table II — LUT-based architectures on JSC (sorted by accuracy; 'ours' measured, 'paper' quoted)",
        &["model", "src", "acc%", "LUT", "FF", "Fmax(MHz)", "Lat(ns)", "AxD"],
    );
    for (_, r) in &rows {
        t.row(r);
    }
    print!("{}", t.render());
    t.write_csv(&artifacts.results_dir().join("table2.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("table2.csv").display());
}

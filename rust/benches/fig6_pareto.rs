//! Fig. 6: Pareto frontier of LUT-based architectures on JSC — LUTs (log
//! scale in the paper) vs accuracy. Emits every design point (our measured
//! DWN-TEN / DWN-PEN / DWN-PEN+FT and TreeLUT baselines + the paper's
//! published points) and marks which are Pareto-optimal.

use dwn::baselines::gbdt::{self, GbdtConfig};
use dwn::baselines::published::TABLE2_PUBLISHED;
use dwn::baselines::treelut;
use dwn::config::Artifacts;
use dwn::data::Dataset;
use dwn::model::{DwnModel, Variant};
use dwn::report::{measure, Table};
use dwn::techmap::map6;

#[derive(Clone)]
struct Point {
    name: String,
    src: &'static str,
    acc: f64, // percent
    luts: usize,
}

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let mut pts: Vec<Point> = Vec::new();
    for name in ["sm-10", "sm-50", "md-360", "lg-2400"] {
        let Ok(model) = DwnModel::load(&artifacts.model_path(name)) else { continue };
        for v in [Variant::Ten, Variant::Pen, Variant::PenFt] {
            let r = measure(&model, v).unwrap();
            pts.push(Point {
                name: format!("DWN-{} ({name})", v.label()),
                src: "ours",
                acc: r.acc * 100.0,
                luts: r.timing.luts,
            });
        }
    }
    // TreeLUT baseline sweep (our implementation).
    let train = Dataset::load_csv(&artifacts.dataset_path("train")).unwrap();
    let test = Dataset::load_csv(&artifacts.dataset_path("test")).unwrap();
    for (rounds, depth) in [(2usize, 2usize), (4, 3), (8, 3), (12, 4)] {
        let cfg = GbdtConfig { num_rounds: rounds, max_depth: depth, ..Default::default() };
        let model = gbdt::train(&train, 5, &cfg);
        let xt = gbdt::quantize_dataset(&test, cfg.frac_bits);
        let acc = model.accuracy(&xt, &test.y) * 100.0;
        let design = treelut::build_treelut(&model).unwrap();
        let nl = map6(&design.net);
        pts.push(Point {
            name: format!("TreeLUT-ours (r{rounds} d{depth})"),
            src: "ours",
            acc,
            luts: nl.lut_count(),
        });
    }
    // LogicNets-lite baseline points.
    for name in ["jsc-s", "jsc-m"] {
        let p = artifacts.root.join("models").join(format!("logicnets-{name}.json"));
        let Ok(model) = dwn::baselines::logicnets::LogicNetsModel::load(&p) else { continue };
        let design = dwn::baselines::logicnets::build_logicnets(&model).unwrap();
        let nl = map6(&design.net);
        pts.push(Point {
            name: format!("LogicNets-lite ({name})"),
            src: "ours",
            acc: model.accuracy(&test, test.len()) * 100.0,
            luts: nl.lut_count(),
        });
    }
    for p in TABLE2_PUBLISHED {
        pts.push(Point { name: p.model.to_string(), src: "paper", acc: p.acc, luts: p.luts });
    }

    // Pareto: a point is optimal if no other point has >= acc and < LUTs.
    let pareto: Vec<bool> = pts
        .iter()
        .map(|p| {
            !pts.iter().any(|q| q.acc >= p.acc && q.luts < p.luts && (q.acc > p.acc || q.luts < p.luts))
        })
        .collect();

    let mut sorted: Vec<(usize, &Point)> = pts.iter().enumerate().collect();
    sorted.sort_by(|a, b| b.1.acc.partial_cmp(&a.1.acc).unwrap());
    let mut t = Table::new(
        "Fig. 6 — Pareto frontier, LUTs vs accuracy (JSC)",
        &["design", "src", "acc%", "LUTs", "pareto"],
    );
    for (i, p) in sorted {
        t.row(&[
            p.name.clone(),
            p.src.into(),
            format!("{:.1}", p.acc),
            p.luts.to_string(),
            if pareto[i] { "*".into() } else { "".into() },
        ]);
    }
    print!("{}", t.render());
    t.write_csv(&artifacts.results_dir().join("fig6_pareto.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("fig6_pareto.csv").display());
}

//! Fig. 5: component LUT breakdown (encoder / LUT layer / popcount / argmax)
//! for the PEN+FT models across input bit-widths, with the corresponding
//! accuracy from the fine-tuning sweep. Bit-width variation re-quantizes the
//! float thresholds at each width (PTQ), exactly like the paper's sweep.

use dwn::config::Artifacts;
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::model::{DwnModel, Variant};
use dwn::report::Table;
use dwn::techmap::MapConfig;
use dwn::util::fixed;

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let mut t = Table::new(
        "Fig. 5 — component breakdown of DWN-PEN(+FT) vs input bit-width",
        &["model", "bits", "acc_pen%", "acc_penft%", "encoder", "lut-layer", "popcount", "argmax", "total"],
    );
    for name in ["sm-10", "sm-50", "md-360", "lg-2400"] {
        let Ok(mut model) = DwnModel::load(&artifacts.model_path(name)) else { continue };
        let sweep = model.bw_sweep.clone();
        for point in &sweep {
            // Re-quantize the float thresholds at this bit-width (the PEN
            // mapping/tables stay fixed; accuracy comes from the sweep data).
            let bw = point.frac_bits;
            model.pen_threshold_ints = model
                .thresholds
                .iter()
                .map(|row| row.iter().map(|&t| fixed::threshold_to_int(t, bw)).collect())
                .collect();
            // Overwrite the PEN frac_bits for this synthetic variant.
            model.pen.frac_bits = Some(bw);
            let accel = build_accelerator(&model, &AccelOptions::new(Variant::Pen)).unwrap();
            let (nl, bd) = accel.map_with_breakdown(&MapConfig::default());
            let get = |c: Component| {
                bd.iter().find(|(k, _)| *k == c).map(|(_, n)| *n).unwrap_or(0).to_string()
            };
            t.row(&[
                name.into(),
                bw.to_string(),
                format!("{:.1}", point.acc_pen * 100.0),
                format!("{:.1}", point.acc_penft * 100.0),
                get(Component::Encoder),
                get(Component::LutLayer),
                get(Component::Popcount),
                get(Component::Argmax),
                nl.lut_count().to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    t.write_csv(&artifacts.results_dir().join("fig5_breakdown.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("fig5_breakdown.csv").display());
}

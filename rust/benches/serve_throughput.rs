//! Serving-throughput bench: interpreter (`LutNetlist::eval_lanes`) vs the
//! compiled execution engine (`dwn::engine`) across the head×tail mode
//! matrix and batch sizes, in rows/sec, on a JSC-sized PEN+FT accelerator.
//! Falls back to a synthetic model of the same shape when trained artifacts
//! are absent, so it runs anywhere.
//!
//! Engine arms (head/tail), all behind the persistent worker pool:
//! * `lut/lut`       — full LUT emulation (the PR 2 plan behind the pool).
//! * `native/lut`    — native thermometer head, emulated tail.
//! * `lut/native`    — emulated encoder, native popcount/argmax tail.
//! * `native/native` — the serving default: only the LUT layers are
//!   emulated.
//!
//! A final `server` arm drives the native/native plan through a full
//! [`Server`] — admission, bounded queue, double-buffered batch loop — in a
//! closed loop at small windows (batch ≤ 64), where per-row compute is
//! cheapest relative to coordination: rows/sec there isolates coordinator
//! overhead, the convoy/copy cost this PR removes.
//!
//! Every pool arm runs twice: once with per-op dispatch (`engine: pool`)
//! and once with the per-table fused dispatch schedule (`engine: fused`) —
//! same plans, same rows — and a `fused` section records the head-to-head
//! (rows/sec both ways plus `decisions_equal`, asserted true and gated in
//! CI: fused must be a pure dispatch change, never a semantic one).
//!
//! Besides the table, the run writes `BENCH_serve.json` so the perf
//! trajectory is machine-readable across PRs: per arm per batch rows/sec
//! plus batch-call latency percentiles (p50/p99/p999/max, log-bucket
//! histogram) — each arm record carries an `engine` field naming its
//! registry backend — an `opt` section per head×tail arm (netlist area and
//! rows/sec before vs after the `--opt-level` max pass pipeline), a
//! `stage_breakdown` per head×tail pool arm (head-pack / lut-exec / tail
//! percentiles from the pool's telemetry, plus the pool's
//! runtime-activity summary — per-level ns and sampled output density), and
//! the server arm's full metrics snapshot (per-stage table, shed/overlap
//! counters, and its own `activity` block).
//! `DWN_BENCH_QUICK=1` shrinks iteration counts for CI smoke runs.
//!
//!     cargo bench --bench serve_throughput
//!     (or: target/release/serve_throughput after `cargo build --benches`)

use dwn::config::Artifacts;
use dwn::coordinator::{AdmissionPolicy, Backend, Row, Server, ServerConfig};
use dwn::engine::backend::PooledModel;
use dwn::engine::{HeadMode, TailMode};
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::json::Value;
use dwn::model::{DwnModel, SynthSpec, Variant};
use dwn::techmap::MapConfig;
use dwn::telemetry::{HistSummary, LatencyHistogram, Stage};
use dwn::util::SplitMix64;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Rows per timing rep; quick mode (`DWN_BENCH_QUICK=1`) keeps CI smoke
/// runs in seconds. `0`/`false`/empty explicitly select the full run.
fn target_rows() -> usize {
    let quick = !matches!(
        std::env::var("DWN_BENCH_QUICK").as_deref(),
        Err(_) | Ok("") | Ok("0") | Ok("false")
    );
    if quick {
        4_096
    } else {
        65_536
    }
}

const MODES: [(HeadMode, TailMode); 4] = [
    (HeadMode::Lut, TailMode::Lut),
    (HeadMode::Native, TailMode::Lut),
    (HeadMode::Lut, TailMode::Native),
    (HeadMode::Native, TailMode::Native),
];

fn main() {
    let artifacts = Artifacts::discover();
    let model = if artifacts.exists() {
        match DwnModel::load(&artifacts.model_path("md-360")) {
            Ok(m) => {
                println!("model: md-360 (trained artifacts)");
                m
            }
            Err(_) => synth(),
        }
    } else {
        synth()
    };

    let frac_bits = model.penft.frac_bits.expect("penft bits");
    let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
    let (nl, tags, head, tail) = accel.map_with_head(&MapConfig::default());
    let index_width = accel.index_width();
    let plans: Vec<dwn::engine::ExecPlan> = MODES
        .iter()
        .map(|&(hm, tm)| {
            dwn::engine::compile_for_modes(&nl, Some(&tags), head.as_ref(), tail.as_ref(), hm, tm)
        })
        .collect();
    let base = &plans[0];
    println!(
        "accelerator: {} LUTs -> {} compiled ops / {} levels ({} const-folded, {} dead, {} pins folded)",
        nl.lut_count(),
        base.ops.len(),
        base.depth(),
        base.stats.const_folded,
        base.stats.dead_eliminated,
        base.stats.pins_folded
    );
    let full = &plans[3];
    println!(
        "native head+tail: {} ops / {} levels ({} encoder LUTs{} and {} popcount/argmax LUTs{} evaluated natively)",
        full.ops.len(),
        full.depth(),
        full.stats.head_skipped,
        if full.head.is_some() { "" } else { "; head UNAVAILABLE — fell back to lut" },
        full.stats.tail_skipped,
        if full.tail.is_some() { "" } else { "; tail UNAVAILABLE — fell back to lut" }
    );
    // Pass-pipeline outcome at `--opt-level` max, shared by every opt arm:
    // the pipeline is a netlist transform, so it runs once and each mode
    // compiles from the optimized netlist + rebuilt head/tail metadata.
    let outcome = dwn::engine::run_pipeline(
        &nl,
        Some(&tags),
        head.as_ref(),
        tail.as_ref(),
        dwn::engine::OptLevel::Max,
    );
    let opt_plans: Vec<dwn::engine::ExecPlan> =
        MODES.iter().map(|&(hm, tm)| outcome.compile_for_modes(hm, tm)).collect();
    println!(
        "opt passes (-O2): {} -> {} LUTs in {} sweep(s) ({} const, {} coalesced, {} dead, {} pins folded)",
        nl.lut_count(),
        outcome.netlist.lut_count(),
        outcome.stats.iterations,
        outcome.stats.const_folded,
        outcome.stats.coalesced,
        outcome.stats.dead_removed,
        outcome.stats.pins_folded
    );

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let interp =
        Backend::netlist(nl, frac_bits, model.num_features, model.num_classes, index_width);
    // Persistent pools, held across all batches like a real server. The
    // fused twins share the exact plans but dispatch per canonical truth
    // table instead of per op (DESIGN.md §engine).
    let pools: Vec<Backend> = plans
        .iter()
        .map(|p| {
            Backend::compiled(
                p.clone(),
                frac_bits,
                model.num_features,
                model.num_classes,
                index_width,
                256,
                cores,
            )
        })
        .collect();
    let fused_pools: Vec<Backend> = plans
        .iter()
        .map(|p| {
            Backend::from_model(Box::new(PooledModel::from_plan(
                std::sync::Arc::new(p.clone()),
                frac_bits,
                model.num_features,
                model.num_classes,
                index_width,
                256,
                cores,
                true,
            )))
        })
        .collect();

    // Random feature rows (eval cost is data-independent), admitted once
    // into shared `Row`s — every arm reuses the same allocations.
    let mut rng = SplitMix64::new(0xBEEF);
    let rows: Vec<Row> = (0..4096)
        .map(|_| {
            Row::from(
                (0..model.num_features)
                    .map(|_| (2.0 * rng.next_f64() - 1.0) as f32)
                    .collect::<Vec<f32>>(),
            )
        })
        .collect();

    println!(
        "\n{:>7} {:>14} {:>13} {:>13} {:>13} {:>13} {:>8}",
        "batch", "interp r/s", "lut/lut", "native/lut", "lut/native", "native/native", "gain"
    );
    let mut records: Vec<Value> = Vec::new();
    for batch in [64usize, 256, 1024, 4096] {
        let slice = &rows[..batch];
        let (interp_rps, interp_lat) = rows_per_sec(slice, |r| interp.infer(r).unwrap());
        records.push(arm_record("interp", "interp", "-", "-", batch, interp_rps, &interp_lat));
        let mut rps = [0f64; 4];
        for (i, pool) in pools.iter().enumerate() {
            let (arm_rps, lat) = rows_per_sec(slice, |r| pool.infer(r).unwrap());
            rps[i] = arm_rps;
            let (hm, tm) = MODES[i];
            records
                .push(arm_record("pool", "pool", hm.label(), tm.label(), batch, arm_rps, &lat));
        }
        for (i, fp) in fused_pools.iter().enumerate() {
            let (arm_rps, lat) = rows_per_sec(slice, |r| fp.infer(r).unwrap());
            let (hm, tm) = MODES[i];
            records
                .push(arm_record("pool", "fused", hm.label(), tm.label(), batch, arm_rps, &lat));
        }
        println!(
            "{:>7} {:>14.0} {:>13.0} {:>13.0} {:>13.0} {:>13.0} {:>7.2}x",
            batch,
            interp_rps,
            rps[0],
            rps[1],
            rps[2],
            rps[3],
            // the tentpole gain: both boundaries native vs full emulation
            rps[3] / rps[0]
        );
    }
    // Opt-level delta arms: per head×tail mode, the base plan vs the plan
    // compiled from the pass-optimized netlist, at one fixed batch through
    // persistent pools. Decisions are asserted identical before timing, so
    // this section doubles as an end-to-end smoke check of the pipeline.
    let opt_batch = 1024usize.min(rows.len());
    let mut opt_records: Vec<Value> = Vec::new();
    println!("\nopt-level 2 delta (batch {opt_batch}):");
    println!(
        "{:>14} {:>10} {:>10} {:>13} {:>13} {:>7}",
        "head/tail", "luts", "luts-opt", "base r/s", "opt r/s", "gain"
    );
    for (i, &(hm, tm)) in MODES.iter().enumerate() {
        let opt_pool = Backend::compiled(
            opt_plans[i].clone(),
            frac_bits,
            model.num_features,
            model.num_classes,
            index_width,
            256,
            cores,
        );
        let slice = &rows[..opt_batch];
        assert_eq!(
            pools[i].infer(slice).unwrap(),
            opt_pool.infer(slice).unwrap(),
            "opt plan diverged for {}/{}",
            hm.label(),
            tm.label()
        );
        let (base_rps, _) = rows_per_sec(slice, |r| pools[i].infer(r).unwrap());
        let (opt_rps, _) = rows_per_sec(slice, |r| opt_pool.infer(r).unwrap());
        let mut m = BTreeMap::new();
        m.insert("head".to_string(), Value::Str(hm.label().to_string()));
        m.insert("tail".to_string(), Value::Str(tm.label().to_string()));
        m.insert("batch".to_string(), Value::Num(opt_batch as f64));
        m.insert("luts_before".to_string(), Value::Num(nl_luts(&plans[i]) as f64));
        m.insert(
            "luts_after".to_string(),
            Value::Num(outcome.netlist.lut_count() as f64),
        );
        m.insert("ops".to_string(), Value::Num(plans[i].ops.len() as f64));
        m.insert("ops_opt".to_string(), Value::Num(opt_plans[i].ops.len() as f64));
        m.insert("rows_per_sec".to_string(), Value::Num(base_rps.round()));
        m.insert("rows_per_sec_opt".to_string(), Value::Num(opt_rps.round()));
        opt_records.push(Value::Obj(m));
        println!(
            "{:>14} {:>10} {:>10} {:>13.0} {:>13.0} {:>6.2}x",
            format!("{}/{}", hm.label(), tm.label()),
            nl_luts(&plans[i]),
            outcome.netlist.lut_count(),
            base_rps,
            opt_rps,
            opt_rps / base_rps.max(1e-9)
        );
    }

    // Fused-dispatch head-to-head: per head×tail mode, per-op dispatch vs
    // the per-table fused schedule over the identical plan and rows, at one
    // fixed batch. Decisions are asserted equal before timing — the fused
    // schedule only permutes ops within a level, and levelization makes
    // that bit-identical — so `decisions_equal` doubles as the bench-side
    // conformance gate CI checks in BENCH_serve.json.
    let fused_batch = 1024usize.min(rows.len());
    let mut fused_records: Vec<Value> = Vec::new();
    println!("\nfused dispatch delta (batch {fused_batch}):");
    println!(
        "{:>14} {:>13} {:>13} {:>7}",
        "head/tail", "pool r/s", "fused r/s", "gain"
    );
    for (i, &(hm, tm)) in MODES.iter().enumerate() {
        let slice = &rows[..fused_batch];
        let decisions_equal = pools[i].infer(slice).unwrap() == fused_pools[i].infer(slice).unwrap();
        assert!(decisions_equal, "fused dispatch diverged for {}/{}", hm.label(), tm.label());
        let (pool_rps, _) = rows_per_sec(slice, |r| pools[i].infer(r).unwrap());
        let (fused_rps, _) = rows_per_sec(slice, |r| fused_pools[i].infer(r).unwrap());
        let mut m = BTreeMap::new();
        m.insert("head".to_string(), Value::Str(hm.label().to_string()));
        m.insert("tail".to_string(), Value::Str(tm.label().to_string()));
        m.insert("batch".to_string(), Value::Num(fused_batch as f64));
        m.insert("rows_per_sec_pool".to_string(), Value::Num(pool_rps.round()));
        m.insert("rows_per_sec_fused".to_string(), Value::Num(fused_rps.round()));
        m.insert("decisions_equal".to_string(), Value::Bool(decisions_equal));
        fused_records.push(Value::Obj(m));
        println!(
            "{:>14} {:>13.0} {:>13.0} {:>6.2}x",
            format!("{}/{}", hm.label(), tm.label()),
            pool_rps,
            fused_rps,
            fused_rps / pool_rps.max(1e-9)
        );
    }

    // Coordinator-overhead arm: the native/native plan behind a full
    // Server, driven closed-loop at small windows. At batch <= 64 the
    // engine work per pass is tiny, so rows/sec here is dominated by
    // admission + queue + batch assembly + reply splicing — exactly the
    // hot path the zero-copy/double-buffer rework targets.
    let server = Server::start_compiled(
        plans[3].clone(),
        frac_bits,
        model.num_features,
        model.num_classes,
        index_width,
        256,
        cores,
        ServerConfig {
            max_batch: 256,
            max_wait: Duration::from_micros(100),
            queue_depth: 8192,
            admission: AdmissionPolicy::Shed,
            ..ServerConfig::default()
        },
    );
    println!("\n{:>7} {:>14}   (closed-loop server, native/native)", "window", "server r/s");
    for window in [16usize, 64] {
        let rps = server_rows_per_sec(&server, &rows, window);
        // Server-arm percentiles are true per-request end-to-end latencies
        // from the coordinator's own histograms (cumulative over windows).
        let snap = server.metrics.snapshot();
        let lat = HistSummary {
            count: snap.requests,
            p50_ns: snap.p50_us * 1000,
            p99_ns: snap.p99_us * 1000,
            p999_ns: snap.p999_us * 1000,
            max_ns: snap.max_us * 1000,
            mean_ns: 0.0,
        };
        records.push(arm_record("server", "pool", "native", "native", window, rps, &lat));
        println!("{:>7} {:>14.0}", window, rps);
    }

    // Per head×tail pool arm: engine-side stage percentiles accumulated over
    // every batch size the arm served above, plus the pool's runtime-activity
    // summary (per-level ns, sampled output density at the default 1-in-64).
    let mut breakdown: Vec<Value> = Vec::new();
    for (i, pool) in pools.iter().enumerate() {
        let Some(tel) = pool.engine_telemetry() else { continue };
        let (hm, tm) = MODES[i];
        let mut m = BTreeMap::new();
        m.insert("engine".to_string(), Value::Str(pool.engine_name().to_string()));
        m.insert("head".to_string(), Value::Str(hm.label().to_string()));
        m.insert("tail".to_string(), Value::Str(tm.label().to_string()));
        let mut stages = BTreeMap::new();
        for stage in [Stage::HeadPack, Stage::LutExec, Stage::Tail] {
            let s = tel.stages.get(stage).summary();
            if s.count > 0 {
                stages.insert(stage.label().to_string(), summary_json(&s));
            }
        }
        m.insert("stages".to_string(), Value::Obj(stages));
        if let Some(act) = pool.engine_activity() {
            m.insert("activity".to_string(), act.report().to_json());
        }
        breakdown.push(Value::Obj(m));
    }

    let mut top = BTreeMap::new();
    top.insert("model".to_string(), Value::Str(model.name.clone()));
    top.insert("luts".to_string(), Value::Num(nl_luts(&plans[0]) as f64));
    let arm_count = records.len();
    top.insert("arms".to_string(), Value::Arr(records));
    // Per-mode area + rows/sec delta from the `--opt-level` max pipeline:
    // luts_before/luts_after (netlist area), ops/ops_opt (compiled plan
    // size for that mode), rows_per_sec/rows_per_sec_opt.
    top.insert("opt".to_string(), Value::Arr(opt_records));
    // Per-mode fused-vs-pool head-to-head; `decisions_equal` must stay true
    // (CI fails the bench smoke if it ever flips).
    top.insert("fused".to_string(), Value::Arr(fused_records));
    top.insert("stage_breakdown".to_string(), Value::Arr(breakdown));
    // Full coordinator snapshot of the server arm: per-stage rows including
    // queue-wait/batch-form/reply, shed + overlap counters.
    top.insert("server".to_string(), server.metrics.snapshot().to_json());
    let json = dwn::json::write(&Value::Obj(top));
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("\nwrote BENCH_serve.json ({arm_count} arm records)"),
        Err(e) => println!("\ncould not write BENCH_serve.json: {e}"),
    }

    // Per-stage runtime attribution (the paper's area breakdown, extended to
    // emulation throughput), for full emulation vs both boundaries native.
    for (label, plan) in [("lut/lut", &plans[0]), ("native/native", &plans[3])] {
        let mut fill_rng = SplitMix64::new(0xA77);
        let head_rows: Vec<Vec<f32>> = plan
            .head
            .as_ref()
            .map(|h| {
                (0..256)
                    .map(|_| {
                        (0..h.num_features)
                            .map(|_| (2.0 * fill_rng.next_f64() - 1.0) as f32)
                            .collect()
                    })
                    .collect()
            })
            .unwrap_or_default();
        let runtime = dwn::engine::measure_stages(plan, 256, 64, |ex, _| {
            if ex.plan().head.is_some() {
                ex.pack_head_rows(&head_rows, frac_bits);
            } else {
                for i in 0..plan.num_inputs {
                    for w in ex.input_words_mut(i) {
                        *w = fill_rng.next_u64();
                    }
                }
            }
        });
        println!(
            "\nper-stage runtime attribution, {label} (ns/row over {} lanes):",
            runtime.lanes
        );
        let total: f64 = Component::ALL.iter().map(|&c| runtime.ns_per_row(c)).sum::<f64>()
            + runtime.tail_ns_per_row()
            + runtime.head_ns_per_row();
        for c in Component::ALL {
            let ns = runtime.ns_per_row(c);
            println!(
                "  {:14} {:>8.2} ns/row  ({:>5.1}%)",
                c.label(),
                ns,
                100.0 * ns / total.max(1e-9)
            );
        }
        if runtime.head.is_some() {
            let ns = runtime.head_ns_per_row();
            println!(
                "  {:14} {:>8.2} ns/row  ({:>5.1}%)",
                "head-native",
                ns,
                100.0 * ns / total.max(1e-9)
            );
        }
        if runtime.tail.is_some() {
            let ns = runtime.tail_ns_per_row();
            println!(
                "  {:14} {:>8.2} ns/row  ({:>5.1}%)",
                "tail-native",
                ns,
                100.0 * ns / total.max(1e-9)
            );
        }
    }
}

fn synth() -> DwnModel {
    let spec = SynthSpec::jsc_sized();
    println!("model: {} (synthetic, no artifacts)", spec.name);
    DwnModel::synthetic(&spec)
}

fn nl_luts(plan: &dwn::engine::ExecPlan) -> usize {
    plan.stats.source_luts
}

/// Latency percentiles of a [`HistSummary`] as a JSON object (µs).
fn summary_json(s: &HistSummary) -> Value {
    let mut m = BTreeMap::new();
    m.insert("count".to_string(), Value::Num(s.count as f64));
    m.insert("p50_us".to_string(), Value::Num(s.p50_us() as f64));
    m.insert("p99_us".to_string(), Value::Num(s.p99_us() as f64));
    m.insert("p999_us".to_string(), Value::Num(s.p999_us() as f64));
    m.insert("max_us".to_string(), Value::Num(s.max_us() as f64));
    Value::Obj(m)
}

/// One machine-readable arm record for `BENCH_serve.json`: throughput plus
/// the arm's latency percentiles. `engine` names the registry backend the
/// arm ran on (`interp` / `pool` / `fused`) so trajectory tooling can
/// split dispatch strategies without parsing the `backend` label.
fn arm_record(
    backend: &str,
    engine: &str,
    head: &str,
    tail: &str,
    batch: usize,
    rps: f64,
    lat: &HistSummary,
) -> Value {
    let mut m = BTreeMap::new();
    m.insert("backend".to_string(), Value::Str(backend.to_string()));
    m.insert("engine".to_string(), Value::Str(engine.to_string()));
    m.insert("head".to_string(), Value::Str(head.to_string()));
    m.insert("tail".to_string(), Value::Str(tail.to_string()));
    m.insert("batch".to_string(), Value::Num(batch as f64));
    m.insert("rows_per_sec".to_string(), Value::Num(rps.round()));
    m.insert("p50_us".to_string(), Value::Num(lat.p50_us() as f64));
    m.insert("p99_us".to_string(), Value::Num(lat.p99_us() as f64));
    m.insert("p999_us".to_string(), Value::Num(lat.p999_us() as f64));
    m.insert("max_us".to_string(), Value::Num(lat.max_us() as f64));
    Value::Obj(m)
}

/// Median-of-3 timed repetitions, enough iterations to amortize noise.
/// Also histograms every timed batch-call latency (log-bucket, O(1) memory)
/// and returns its percentile summary alongside the median throughput.
fn rows_per_sec(rows: &[Row], infer: impl Fn(&[Row]) -> Vec<i32>) -> (f64, HistSummary) {
    let iters = (target_rows() / rows.len()).max(1);
    let _ = infer(rows); // warmup
    let hist = LatencyHistogram::new();
    let mut samples: Vec<f64> = (0..3)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                let tc = Instant::now();
                let preds = infer(rows);
                hist.record(tc.elapsed());
                assert_eq!(preds.len(), rows.len());
            }
            (iters * rows.len()) as f64 / t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[1], hist.summary())
}

/// Closed-loop serving throughput: keep `window` requests in flight through
/// the full coordinator (zero-copy resubmission of cached rows), drain, and
/// repeat. Median of 3 reps, like [`rows_per_sec`].
fn server_rows_per_sec(server: &Server, rows: &[Row], window: usize) -> f64 {
    let iters = (target_rows() / window).max(1);
    let run = || {
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(window);
        for it in 0..iters {
            for k in 0..window {
                let row = rows[(it * window + k) % rows.len()].clone();
                pending.push(server.submit_row(row).expect("bench queue sized for window"));
            }
            for rx in pending.drain(..) {
                let _ = rx.recv().expect("server reply");
            }
        }
        (iters * window) as f64 / t0.elapsed().as_secs_f64()
    };
    let _ = run(); // warmup
    let mut samples: Vec<f64> = (0..3).map(|_| run()).collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[1]
}

//! Serving-throughput bench: interpreter (`LutNetlist::eval_lanes`) vs the
//! compiled execution engine (`dwn::engine`) across batch sizes, in rows/sec,
//! on a JSC-sized PEN+FT accelerator. Falls back to a synthetic model of the
//! same shape when trained artifacts are absent, so it runs anywhere.
//!
//!     cargo bench --bench serve_throughput
//!     (or: target/release/serve_throughput after `cargo build --benches`)

use dwn::config::Artifacts;
use dwn::coordinator::Backend;
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::model::{DwnModel, SynthSpec, Variant};
use dwn::techmap::MapConfig;
use dwn::util::SplitMix64;
use std::time::Instant;

fn main() {
    let artifacts = Artifacts::discover();
    let model = if artifacts.exists() {
        match DwnModel::load(&artifacts.model_path("md-360")) {
            Ok(m) => {
                println!("model: md-360 (trained artifacts)");
                m
            }
            Err(_) => synth(),
        }
    } else {
        synth()
    };

    let frac_bits = model.penft.frac_bits.expect("penft bits");
    let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
    let (nl, tags) = accel.map_with_stages(&MapConfig::default());
    let plan = dwn::engine::compile_with_stages(&nl, Some(&tags));
    let index_width = accel.index_width();
    println!(
        "accelerator: {} LUTs -> {} compiled ops / {} levels ({} const-folded, {} dead, {} pins folded)",
        nl.lut_count(),
        plan.ops.len(),
        plan.depth(),
        plan.stats.const_folded,
        plan.stats.dead_eliminated,
        plan.stats.pins_folded
    );

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let interp = Backend::Netlist {
        netlist: nl,
        frac_bits,
        num_features: model.num_features,
        num_classes: model.num_classes,
        index_width,
    };
    let mk_compiled = |lanes: usize, threads: usize| Backend::Compiled {
        plan: plan.clone(),
        frac_bits,
        num_features: model.num_features,
        num_classes: model.num_classes,
        index_width,
        lanes,
        threads,
    };
    let compiled_1t = mk_compiled(256, 1);
    let compiled_nt = mk_compiled(256, cores);

    // Random feature rows (eval cost is data-independent).
    let mut rng = SplitMix64::new(0xBEEF);
    let rows: Vec<Vec<f32>> = (0..4096)
        .map(|_| {
            (0..model.num_features).map(|_| (2.0 * rng.next_f64() - 1.0) as f32).collect()
        })
        .collect();

    println!(
        "\n{:>7} {:>18} {:>18} {:>18} {:>9}",
        "batch", "interp rows/s", "compiled-1t rows/s", &format!("compiled-{cores}t rows/s"), "speedup"
    );
    for batch in [64usize, 256, 1024, 4096] {
        let slice = &rows[..batch];
        let interp_rps = rows_per_sec(&interp, slice);
        let c1_rps = rows_per_sec(&compiled_1t, slice);
        let cn_rps = rows_per_sec(&compiled_nt, slice);
        println!(
            "{:>7} {:>18.0} {:>18.0} {:>18.0} {:>8.2}x",
            batch,
            interp_rps,
            c1_rps,
            cn_rps,
            cn_rps.max(c1_rps) / interp_rps
        );
    }

    // Per-stage runtime attribution (the paper's area breakdown, extended to
    // emulation throughput).
    let mut fill_rng = SplitMix64::new(0xA77);
    let runtime =
        dwn::engine::measure_stages(&plan, 256, 64, |ex, _| {
            for i in 0..plan.num_inputs {
                for w in ex.input_words_mut(i) {
                    *w = fill_rng.next_u64();
                }
            }
        });
    println!("\nper-stage runtime attribution (ns/row over {} lanes):", runtime.lanes);
    let total: f64 = Component::ALL.iter().map(|&c| runtime.ns_per_row(c)).sum();
    for c in Component::ALL {
        let ns = runtime.ns_per_row(c);
        println!("  {:9} {:>8.2} ns/row  ({:>5.1}%)", c.label(), ns, 100.0 * ns / total.max(1e-9));
    }
}

fn synth() -> DwnModel {
    let spec = SynthSpec::jsc_sized();
    println!("model: {} (synthetic, no artifacts)", spec.name);
    DwnModel::synthetic(&spec)
}

/// Median-of-3 timed repetitions, enough iterations to amortize noise.
fn rows_per_sec(backend: &Backend, rows: &[Vec<f32>]) -> f64 {
    let iters = (65_536 / rows.len()).max(1);
    let _ = backend.infer(rows).unwrap(); // warmup
    let mut samples: Vec<f64> = (0..3)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                let preds = backend.infer(rows).unwrap();
                assert_eq!(preds.len(), rows.len());
            }
            (iters * rows.len()) as f64 / t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[1]
}

//! Serving-throughput bench: interpreter (`LutNetlist::eval_lanes`) vs the
//! compiled execution engine (`dwn::engine`) across batch sizes, in rows/sec,
//! on a JSC-sized PEN+FT accelerator. Falls back to a synthetic model of the
//! same shape when trained artifacts are absent, so it runs anywhere.
//!
//! Engine configurations, against the interpreter baseline:
//! * `spawn-lut`  — PR 2 engine: full LUT emulation, scoped threads spawned
//!   per batch (`engine::infer_fixed_batch`).
//! * `pool-lut`   — same plan behind the persistent worker pool.
//! * `pool-native`— plan truncated at the LUT→arithmetic boundary with the
//!   native popcount/argmax tail, behind the pool — the serving default.
//!
//!     cargo bench --bench serve_throughput
//!     (or: target/release/serve_throughput after `cargo build --benches`)

use dwn::config::Artifacts;
use dwn::coordinator::Backend;
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::model::{DwnModel, SynthSpec, Variant};
use dwn::techmap::MapConfig;
use dwn::util::SplitMix64;
use std::time::Instant;

fn main() {
    let artifacts = Artifacts::discover();
    let model = if artifacts.exists() {
        match DwnModel::load(&artifacts.model_path("md-360")) {
            Ok(m) => {
                println!("model: md-360 (trained artifacts)");
                m
            }
            Err(_) => synth(),
        }
    } else {
        synth()
    };

    let frac_bits = model.penft.frac_bits.expect("penft bits");
    let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
    let (nl, tags, tail) = accel.map_with_tail(&MapConfig::default());
    let lut_plan = dwn::engine::compile_with_stages(&nl, Some(&tags));
    let native_plan = dwn::engine::compile_with_tail(&nl, Some(&tags), tail.as_ref());
    let index_width = accel.index_width();
    println!(
        "accelerator: {} LUTs -> {} compiled ops / {} levels ({} const-folded, {} dead, {} pins folded)",
        nl.lut_count(),
        lut_plan.ops.len(),
        lut_plan.depth(),
        lut_plan.stats.const_folded,
        lut_plan.stats.dead_eliminated,
        lut_plan.stats.pins_folded
    );
    println!(
        "native tail: {} ops / {} levels ({} popcount/argmax LUTs evaluated arithmetically{})",
        native_plan.ops.len(),
        native_plan.depth(),
        native_plan.stats.tail_skipped,
        if native_plan.tail.is_some() { "" } else { "; UNAVAILABLE — fell back to lut" }
    );

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let interp = Backend::Netlist {
        netlist: nl,
        frac_bits,
        num_features: model.num_features,
        num_classes: model.num_classes,
        index_width,
    };
    // Persistent pools, held across all batches like a real server.
    let pool_lut = Backend::compiled(
        lut_plan.clone(),
        frac_bits,
        model.num_features,
        model.num_classes,
        index_width,
        256,
        cores,
    );
    let pool_native = Backend::compiled(
        native_plan.clone(),
        frac_bits,
        model.num_features,
        model.num_classes,
        index_width,
        256,
        cores,
    );

    // Random feature rows (eval cost is data-independent).
    let mut rng = SplitMix64::new(0xBEEF);
    let rows: Vec<Vec<f32>> = (0..4096)
        .map(|_| {
            (0..model.num_features).map(|_| (2.0 * rng.next_f64() - 1.0) as f32).collect()
        })
        .collect();

    println!(
        "\n{:>7} {:>16} {:>16} {:>16} {:>16} {:>9}",
        "batch", "interp rows/s", "spawn-lut rows/s", "pool-lut rows/s", "pool-native r/s", "gain"
    );
    for batch in [64usize, 256, 1024, 4096] {
        let slice = &rows[..batch];
        let interp_rps = rows_per_sec(slice, |r| interp.infer(r).unwrap());
        // PR 2 baseline: scoped-thread spawn per batch, LUT-emulated tail.
        let spawn_rps = rows_per_sec(slice, |r| {
            dwn::engine::infer_fixed_batch(&lut_plan, r, frac_bits, index_width, 256, cores)
        });
        let pool_lut_rps = rows_per_sec(slice, |r| pool_lut.infer(r).unwrap());
        let pool_native_rps = rows_per_sec(slice, |r| pool_native.infer(r).unwrap());
        println!(
            "{:>7} {:>16.0} {:>16.0} {:>16.0} {:>16.0} {:>8.2}x",
            batch,
            interp_rps,
            spawn_rps,
            pool_lut_rps,
            pool_native_rps,
            // the tentpole gain: native tail + persistent pool vs PR 2
            pool_native_rps / spawn_rps
        );
    }

    // Per-stage runtime attribution (the paper's area breakdown, extended to
    // emulation throughput), for both tail modes.
    for (label, plan) in [("lut tail", &lut_plan), ("native tail", &native_plan)] {
        let mut fill_rng = SplitMix64::new(0xA77);
        let runtime = dwn::engine::measure_stages(plan, 256, 64, |ex, _| {
            for i in 0..plan.num_inputs {
                for w in ex.input_words_mut(i) {
                    *w = fill_rng.next_u64();
                }
            }
        });
        println!(
            "\nper-stage runtime attribution, {label} (ns/row over {} lanes):",
            runtime.lanes
        );
        let total: f64 = Component::ALL.iter().map(|&c| runtime.ns_per_row(c)).sum::<f64>()
            + runtime.tail_ns_per_row();
        for c in Component::ALL {
            let ns = runtime.ns_per_row(c);
            println!(
                "  {:11} {:>8.2} ns/row  ({:>5.1}%)",
                c.label(),
                ns,
                100.0 * ns / total.max(1e-9)
            );
        }
        if runtime.tail.is_some() {
            let ns = runtime.tail_ns_per_row();
            println!(
                "  {:11} {:>8.2} ns/row  ({:>5.1}%)",
                "tail-native",
                ns,
                100.0 * ns / total.max(1e-9)
            );
        }
    }
}

fn synth() -> DwnModel {
    let spec = SynthSpec::jsc_sized();
    println!("model: {} (synthetic, no artifacts)", spec.name);
    DwnModel::synthetic(&spec)
}

/// Median-of-3 timed repetitions, enough iterations to amortize noise.
fn rows_per_sec(rows: &[Vec<f32>], infer: impl Fn(&[Vec<f32>]) -> Vec<i32>) -> f64 {
    let iters = (65_536 / rows.len()).max(1);
    let _ = infer(rows); // warmup
    let mut samples: Vec<f64> = (0..3)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                let preds = infer(rows);
                assert_eq!(preds.len(), rows.len());
            }
            (iters * rows.len()) as f64 / t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[1]
}

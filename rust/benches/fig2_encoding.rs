//! Fig. 2: distributive vs uniform thermometer encoding of the first JSC
//! test sample — per-feature activated-bit counts under both schemes, plus
//! the accuracy impact (the reason the paper pays for distributive encoders)
//! and, since the encoding subsystem landed, a side-by-side comparison of
//! every encoder micro-architecture on the same model.
//!
//! `DWN_FIG2_VARIANT=pen|penft` selects the encoder variant (default penft).

use dwn::config::Artifacts;
use dwn::data::Dataset;
use dwn::encoding::EncoderStrategy;
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::model::{DwnModel, Variant};
use dwn::report::Table;
use dwn::techmap::MapConfig;

fn encode_counts(x: &[f32], thresholds: &[Vec<f64>]) -> Vec<usize> {
    x.iter()
        .zip(thresholds)
        .map(|(&v, th)| th.iter().filter(|&&t| v as f64 >= t).count())
        .collect()
}

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let model = DwnModel::load(&artifacts.model_path("sm-50")).expect("model");
    let test = Dataset::load_csv(&artifacts.dataset_path("test")).expect("dataset");
    let x0 = test.row(0);

    let dist = encode_counts(x0, &model.thresholds);
    let unif = encode_counts(x0, &model.uniform_thresholds);
    let t_bits = model.thermo_bits;

    let mut t = Table::new(
        &format!(
            "Fig. 2 — encoding of JSC test sample 0 (T={t_bits} levels/feature): bits set per feature"
        ),
        &["feature", "value", "distributive", "uniform", "delta"],
    );
    for f in 0..model.num_features {
        t.row(&[
            format!("f{f}"),
            format!("{:+.4}", x0[f]),
            dist[f].to_string(),
            unif[f].to_string(),
            format!("{:+}", dist[f] as i64 - unif[f] as i64),
        ]);
    }
    print!("{}", t.render());

    // Quantisation of information: distributive encoding equalises the
    // marginal distribution of set bits (quantile property). Report the
    // spread across the test set as the figure's quantitative counterpart.
    let mut spread = Table::new(
        "Fig. 2b — std of per-feature set-bit counts over 1000 samples (distributive should be higher/flatter)",
        &["scheme", "mean bits set", "std"],
    );
    for (label, th) in [("distributive", &model.thresholds), ("uniform", &model.uniform_thresholds)]
    {
        let n = 1000.min(test.len());
        let mut all = Vec::new();
        for i in 0..n {
            let c = encode_counts(test.row(i), th);
            all.extend(c.into_iter().map(|v| v as f64));
        }
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        let var = all.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / all.len() as f64;
        spread.row(&[label.into(), format!("{mean:.2}"), format!("{:.2}", var.sqrt())]);
    }
    print!("{}", spread.render());
    t.write_csv(&artifacts.results_dir().join("fig2_encoding.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("fig2_encoding.csv").display());

    // Encoder micro-architecture sweep: the same trained model lowered with
    // every encoder strategy, mapped, and attributed (DESIGN.md §encoding).
    let variant: Variant = std::env::var("DWN_FIG2_VARIANT")
        .unwrap_or_else(|_| "penft".to_string())
        .parse()
        .expect("DWN_FIG2_VARIANT");
    assert!(
        variant != Variant::Ten,
        "DWN_FIG2_VARIANT must be a PEN-family variant (pen|penft): TEN has no encoder stage"
    );
    let mut archs = Table::new(
        &format!(
            "Fig. 2c — encoder micro-architectures on {} ({})",
            model.name,
            variant.label()
        ),
        &["strategy", "encoder LUTs", "total LUTs", "depth", "modeled enc LUTs", "distinct cmp"],
    );
    for strategy in [
        EncoderStrategy::Bank,
        EncoderStrategy::Chain,
        EncoderStrategy::Mux,
        EncoderStrategy::Lut,
        EncoderStrategy::Auto,
    ] {
        let accel = build_accelerator(&model, &AccelOptions::new(variant).with_encoder(strategy))
            .expect("build");
        let (nl, counts) = accel.map_with_breakdown(&MapConfig::default());
        let enc = counts
            .iter()
            .find(|(c, _)| *c == Component::Encoder)
            .map(|(_, n)| *n)
            .unwrap_or(0);
        let modeled = accel
            .encoder_plan
            .as_ref()
            .map(|p| p.total_modeled().luts.to_string())
            .unwrap_or_else(|| "-".into());
        archs.row(&[
            strategy.label().into(),
            enc.to_string(),
            nl.lut_count().to_string(),
            nl.depth().to_string(),
            modeled,
            accel.distinct_comparators.to_string(),
        ]);
    }
    print!("{}", archs.render());
    archs
        .write_csv(&artifacts.results_dir().join("fig2_encoder_archs.csv"))
        .expect("csv");
    println!("wrote {}", artifacts.results_dir().join("fig2_encoder_archs.csv").display());
}

//! Table I: DWN-TEN vs DWN-PEN+FT hardware comparison across model sizes.
//! Prints the paper's rows next to ours and writes CSV to artifacts/results.

use dwn::baselines::published::TABLE1_PAPER;
use dwn::config::Artifacts;
use dwn::model::{DwnModel, Variant};
use dwn::report::{f1, int, measure, pct, Table};
use std::time::Instant;

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let mut t = Table::new(
        "Table I — DWN-TEN vs DWN-PEN+FT (ours: in-repo synthesis substrate; paper: Vivado OOC)",
        &["model", "variant", "src", "acc%", "LUT", "FF", "Fmax(MHz)", "Lat(ns)", "AxD(LUT*ns)"],
    );
    let t0 = Instant::now();
    for name in ["lg-2400", "md-360", "sm-50", "sm-10"] {
        let model = match DwnModel::load(&artifacts.model_path(name)) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        for variant in [Variant::Ten, Variant::PenFt] {
            let row = measure(&model, variant).expect("measure");
            t.row(&[
                name.into(),
                variant.label().into(),
                "ours".into(),
                pct(row.acc),
                int(row.timing.luts),
                int(row.timing.ffs),
                f1(row.timing.fmax_mhz),
                f1(row.timing.latency_ns),
                f1(row.timing.area_delay),
            ]);
            if let Some(p) =
                TABLE1_PAPER.iter().find(|p| p.model == name && p.variant == variant.label())
            {
                t.row(&[
                    name.into(),
                    variant.label().into(),
                    "paper".into(),
                    p.acc.map(|a| format!("{a:.1}")).unwrap_or_else(|| "-".into()),
                    int(p.luts),
                    int(p.ffs),
                    f1(p.fmax_mhz),
                    f1(p.latency_ns),
                    f1(p.area_delay),
                ]);
            }
        }
    }
    print!("{}", t.render());
    println!("total bench time: {:.1}s", t0.elapsed().as_secs_f64());
    t.write_csv(&artifacts.results_dir().join("table1.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("table1.csv").display());
}

//! Performance micro/macro benches (criterion is unavailable offline; this
//! is a hand-rolled harness with warmup + repeated timing). Covers the L3
//! hot paths profiled in EXPERIMENTS.md §Perf:
//!   - netlist bit-parallel simulation throughput (samples/s)
//!   - technology-mapping time for the lg-2400 accelerator
//!   - serving throughput/latency via the batching coordinator (netlist +
//!     PJRT backends)

use dwn::config::Artifacts;
use dwn::coordinator::{Backend, Server, ServerConfig};
use dwn::data::Dataset;
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, Variant};
use dwn::runtime::Engine;
use dwn::techmap::MapConfig;
use dwn::util::fixed;
use std::time::{Duration, Instant};

fn time_it<F: FnMut()>(label: &str, iters: usize, mut f: F) -> Duration {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed() / iters as u32;
    println!("{label:55} {per:>12.2?}/iter  ({iters} iters)");
    per
}

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    println!("== perf: generation + mapping ==");
    for name in ["sm-50", "md-360", "lg-2400"] {
        let model = DwnModel::load(&artifacts.model_path(name)).unwrap();
        time_it(&format!("build_accelerator({name}, PEN+FT)"), 3, || {
            let _ = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
        });
        let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
        time_it(&format!("techmap({name}, PEN+FT)"), 3, || {
            let _ = accel.map(&MapConfig::default());
        });
    }

    println!("\n== perf: netlist simulation throughput ==");
    let test = Dataset::load_csv(&artifacts.dataset_path("test")).unwrap();
    for name in ["sm-50", "md-360", "lg-2400"] {
        let model = DwnModel::load(&artifacts.model_path(name)).unwrap();
        let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
        let nl = accel.map(&MapConfig::default());
        let frac_bits = model.penft.frac_bits.unwrap();
        let width = (frac_bits + 1) as usize;
        let n = 4096.min(test.len());
        let vectors: Vec<Vec<bool>> = (0..n)
            .map(|i| {
                let mut bits = Vec::with_capacity(test.num_features * width);
                for &x in test.row(i) {
                    let pat =
                        fixed::int_to_bits(fixed::input_to_int(x as f64, frac_bits), frac_bits);
                    for b in 0..width {
                        bits.push((pat >> b) & 1 == 1);
                    }
                }
                bits
            })
            .collect();
        let t0 = Instant::now();
        let iters = 3usize;
        for _ in 0..iters {
            let _ = nl.eval_batch(&vectors);
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "netlist sim {name:9} {:>10.0} samples/s  ({} LUTs)",
            n as f64 / dt,
            nl.lut_count()
        );
    }

    println!("\n== perf: serving (batching coordinator) ==");
    let name = "sm-50";
    let model = DwnModel::load(&artifacts.model_path(name)).unwrap();
    let requests = 20_000usize;

    // netlist backend
    {
        let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt)).unwrap();
        let nl = accel.map(&MapConfig::default());
        let server = Server::start_netlist(
            nl,
            model.penft.frac_bits.unwrap(),
            model.num_features,
            model.num_classes,
            accel.index_width(),
            ServerConfig::default(),
        );
        run_serving(&server, &test, requests, "netlist");
    }
    // PJRT backend
    {
        let batch = artifacts.hlo_batch().unwrap();
        let hlo = artifacts.hlo_path(name);
        let (features, classes) = (model.num_features, model.num_classes);
        let server = Server::start_with(
            move || Ok(Backend::Pjrt(Engine::load(&hlo, batch, features, classes)?)),
            ServerConfig::default(),
        )
        .unwrap();
        run_serving(&server, &test, requests, "pjrt");
    }
}

fn run_serving(server: &Server, test: &Dataset, requests: usize, label: &str) {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(512);
    for i in 0..requests {
        pending.push(server.submit(test.row(i % test.len())).unwrap());
        if pending.len() >= 512 {
            for rx in pending.drain(..) {
                let _ = rx.recv().unwrap().unwrap();
            }
        }
    }
    for rx in pending.drain(..) {
        let _ = rx.recv().unwrap().unwrap();
    }
    let dt = t0.elapsed();
    let snap = server.metrics.snapshot();
    println!(
        "serve[{label:8}] {:>9.0} req/s  p50={}us p99={}us mean_batch={:.1} batches={}",
        requests as f64 / dt.as_secs_f64(),
        snap.p50_us,
        snap.p99_us,
        snap.mean_batch,
        snap.batches
    );
}

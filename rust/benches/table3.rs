//! Table III: DWN variants (TEN, PEN, PEN+FT) — accuracy, LUTs, bit-width,
//! and the encoding-overhead factors the paper headlines (5.30x -> 3.20x for
//! sm-10; 3.68x -> 1.41x for lg-2400).

use dwn::baselines::published::TABLE3_PAPER;
use dwn::config::Artifacts;
use dwn::model::{DwnModel, Variant};
use dwn::report::{int, measure, pct, Table};

fn main() {
    let artifacts = Artifacts::discover();
    if !artifacts.exists() {
        eprintln!("no artifacts — run `make artifacts` first");
        return;
    }
    let mut t = Table::new(
        "Table III — TEN vs PEN vs PEN+FT (overhead x relative to TEN, as in the paper)",
        &["model", "src", "ft_acc%", "ft_LUT", "ft_over", "ft_BW", "pen_acc%", "pen_LUT", "pen_over", "pen_BW", "ten_acc%", "ten_LUT"],
    );
    for name in ["sm-10", "sm-50", "md-360", "lg-2400"] {
        let Ok(model) = DwnModel::load(&artifacts.model_path(name)) else {
            eprintln!("skipping {name}");
            continue;
        };
        let ten = measure(&model, Variant::Ten).unwrap();
        let pen = measure(&model, Variant::Pen).unwrap();
        let ft = measure(&model, Variant::PenFt).unwrap();
        let over = |x: usize, base: usize| format!("{:.2}x", x as f64 / base as f64);
        t.row(&[
            name.into(),
            "ours".into(),
            pct(ft.acc),
            int(ft.timing.luts),
            over(ft.timing.luts, ten.timing.luts),
            ft.bits.unwrap().to_string(),
            pct(pen.acc),
            int(pen.timing.luts),
            over(pen.timing.luts, ten.timing.luts),
            pen.bits.unwrap().to_string(),
            pct(ten.acc),
            int(ten.timing.luts),
        ]);
        if let Some(p) = TABLE3_PAPER.iter().find(|p| p.model == name) {
            t.row(&[
                name.into(),
                "paper".into(),
                "-".into(),
                int(p.penft_luts),
                over(p.penft_luts, p.ten_luts),
                p.penft_bits.to_string(),
                "-".into(),
                int(p.pen_luts),
                over(p.pen_luts, p.ten_luts),
                p.pen_bits.to_string(),
                "-".into(),
                int(p.ten_luts),
            ]);
        }
    }
    print!("{}", t.render());
    t.write_csv(&artifacts.results_dir().join("table3.csv")).expect("csv");
    println!("wrote {}", artifacts.results_dir().join("table3.csv").display());
}

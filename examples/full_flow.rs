//! END-TO-END DRIVER — exercises every layer of the stack on a real (small)
//! workload and proves they compose:
//!
//!   L2/L1 (build time)  trained DWN + pallas kernels, AOT-lowered to HLO
//!   runtime             PJRT loads + executes the HLO (golden model)
//!   L3 hwgen            gate-level accelerator incl. thermometer encoders
//!   L3 techmap/timing   6-LUT mapping + STA (the paper's Table I numbers)
//!   L3 sim              bit-accurate netlist simulation
//!   coordinator         batched serving over both backends
//!
//! For every model it checks: PJRT output == netlist output == JAX golden
//! vectors, then reports hardware cost + serving throughput. This is the
//! run recorded in EXPERIMENTS.md §End-to-end.

use dwn::config::Artifacts;
use dwn::coordinator::{Backend, Server, ServerConfig};
use dwn::data::Dataset;
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, Variant};
use dwn::runtime::Engine;
use dwn::techmap::MapConfig;
use dwn::timing::{analyze, DelayModel};
use dwn::verify::verify_against_golden;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");
    let test = Dataset::load_csv(&artifacts.dataset_path("test"))?;
    println!("test split: {} samples, {} features", test.len(), test.num_features);

    for name in artifacts.manifest_models()? {
        let model = DwnModel::load(&artifacts.model_path(&name))?;
        println!("\n=== {} ===", model.name);

        // --- 1. golden verification: netlist == JAX for all three variants.
        for variant in [Variant::Ten, Variant::Pen, Variant::PenFt] {
            let out = verify_against_golden(&artifacts, &model, variant, 256)?;
            println!(
                "  netlist vs golden [{:6}]: {}/{} bit-exact",
                variant.label(),
                out.checked - out.mismatches,
                out.checked
            );
            anyhow::ensure!(out.ok(), "golden mismatch for {name} {}", variant.label());
        }

        // --- 2. PJRT runtime equals the generated hardware on live data.
        let frac_bits = model.penft.frac_bits.unwrap();
        let scale = 1.0 / (1u64 << frac_bits) as f32;
        let batch = artifacts.hlo_batch()?;
        let engine =
            Engine::load(&artifacts.hlo_path(&name), batch, model.num_features, model.num_classes)?;
        let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
        let nl = accel.map(&MapConfig::default());
        let n = batch;
        let mut flat = vec![0f32; n * model.num_features];
        let mut vectors = Vec::with_capacity(n);
        for i in 0..n {
            let width = (frac_bits + 1) as usize;
            let mut bits = Vec::with_capacity(model.num_features * width);
            for (j, &x) in test.row(i).iter().enumerate() {
                let k = dwn::util::fixed::input_to_int(x as f64, frac_bits);
                flat[i * model.num_features + j] = k as f32 * scale;
                let pat = dwn::util::fixed::int_to_bits(k, frac_bits);
                for b in 0..width {
                    bits.push((pat >> b) & 1 == 1);
                }
            }
            vectors.push(bits);
        }
        let pjrt_out = engine.execute(&flat)?;
        let hw_out = nl.eval_batch(&vectors);
        let iw = accel.index_width();
        let mut agree = 0usize;
        for i in 0..n {
            let mut hw_pred = 0usize;
            for b in 0..iw {
                if hw_out[i][b] {
                    hw_pred |= 1 << b;
                }
            }
            if hw_pred == pjrt_out.pred[i] as usize {
                agree += 1;
            }
        }
        println!("  PJRT vs netlist on live data: {agree}/{n} agree");
        anyhow::ensure!(agree == n, "PJRT/netlist divergence");

        // --- 3. hardware cost (the paper's metrics).
        let rep = analyze(&nl, &DelayModel::default());
        println!(
            "  hardware: {} LUTs, {} FFs, Fmax {:.0} MHz, latency {:.1} ns, AxD {:.0}",
            rep.luts, rep.ffs, rep.fmax_mhz, rep.latency_ns, rep.area_delay
        );

        // --- 4. serving throughput over the PJRT engine (batched).
        let hlo = artifacts.hlo_path(&name);
        let (features, classes) = (model.num_features, model.num_classes);
        let server = Server::start_with(
            move || Ok(Backend::Pjrt(Engine::load(&hlo, batch, features, classes)?)),
            ServerConfig::default(),
        )?;
        let requests = 5000usize;
        let t0 = Instant::now();
        let mut pending = Vec::new();
        let mut correct = 0usize;
        for i in 0..requests {
            let idx = i % test.len();
            pending.push((idx, server.submit(test.row(idx))?));
            if pending.len() >= 256 {
                for (j, rx) in pending.drain(..) {
                    if rx.recv()?? as usize == test.y[j] as usize {
                        correct += 1;
                    }
                }
            }
        }
        for (j, rx) in pending.drain(..) {
            if rx.recv()?? as usize == test.y[j] as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed();
        let snap = server.metrics.snapshot();
        println!(
            "  serving: {:.0} req/s, p50 {} us, p99 {} us, accuracy {:.4}",
            requests as f64 / dt.as_secs_f64(),
            snap.p50_us,
            snap.p99_us,
            correct as f64 / requests as f64
        );
    }
    println!("\nfull flow OK — all layers compose");
    Ok(())
}

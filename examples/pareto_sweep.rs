//! Design-space exploration beyond the paper's four configs: sweep the
//! input bit-width for every model and chart how the encoder's share of the
//! total LUT budget shrinks as models grow (the paper's Fig. 5 narrative),
//! including the uniform-encoding ablation the paper lists as future work
//! (iii).
//!
//!     cargo run --release --example pareto_sweep

use dwn::config::Artifacts;
use dwn::hwgen::{build_accelerator, AccelOptions, Component};
use dwn::model::{DwnModel, Variant};
use dwn::techmap::MapConfig;
use dwn::util::fixed;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");

    println!(
        "{:>9} {:>5} {:>10} {:>9} {:>9} {:>10} {:>9}",
        "model", "bits", "enc LUTs", "total", "enc %", "uniform", "unif enc"
    );
    for name in ["sm-10", "sm-50", "md-360", "lg-2400"] {
        let Ok(mut model) = DwnModel::load(&artifacts.model_path(name)) else { continue };
        for bw in [4u32, 6, 8, 10] {
            // Re-quantize thresholds at this bit-width (PTQ, mapping fixed).
            model.pen_threshold_ints = model
                .thresholds
                .iter()
                .map(|r| r.iter().map(|&t| fixed::threshold_to_int(t, bw)).collect())
                .collect();
            model.pen.frac_bits = Some(bw);

            let distributive = build_accelerator(&model, &AccelOptions::new(Variant::Pen))?;
            let (nl_d, bd_d) = distributive.map_with_breakdown(&MapConfig::default());
            let enc_d =
                bd_d.iter().find(|(c, _)| *c == Component::Encoder).map(|(_, n)| *n).unwrap_or(0);

            let mut uni_opts = AccelOptions::new(Variant::Pen);
            uni_opts.uniform_encoding = true;
            let uniform = build_accelerator(&model, &uni_opts)?;
            let (nl_u, bd_u) = uniform.map_with_breakdown(&MapConfig::default());
            let enc_u =
                bd_u.iter().find(|(c, _)| *c == Component::Encoder).map(|(_, n)| *n).unwrap_or(0);

            println!(
                "{:>9} {:>5} {:>10} {:>9} {:>8.1}% {:>10} {:>9}",
                name,
                bw,
                enc_d,
                nl_d.lut_count(),
                100.0 * enc_d as f64 / nl_d.lut_count() as f64,
                nl_u.lut_count(),
                enc_u
            );
        }
    }
    println!("\n(uniform encoding shares comparator structure on the fixed grid, trading");
    println!(" the accuracy the paper's Fig. 2 attributes to distributive thresholds)");
    Ok(())
}

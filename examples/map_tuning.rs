//! Mapper-tuning ablation: cut-set size and area-recovery passes vs LUT
//! count and map time (the perf pass's stopping-criteria evidence).
use dwn::config::Artifacts;
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, Variant};
use dwn::techmap::MapConfig;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");
    let model = DwnModel::load(&artifacts.model_path("lg-2400"))?;
    let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
    println!("{:>8} {:>6} {:>8} {:>8} {:>9}", "cuts", "passes", "LUTs", "depth", "time");
    for (cuts, passes) in [(4usize, 1usize), (8, 2), (12, 2), (8, 4), (16, 3)] {
        let cfg = MapConfig { k: 6, cut_set_size: cuts, area_passes: passes };
        let t0 = Instant::now();
        let nl = accel.map(&cfg);
        println!(
            "{:>8} {:>6} {:>8} {:>8} {:>8.0}ms",
            cuts, passes, nl.lut_count(), nl.depth(), t0.elapsed().as_millis()
        );
    }
    Ok(())
}

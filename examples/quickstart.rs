//! Quickstart: load a trained DWN model, generate its FPGA hardware with
//! the thermometer-encoding stage included, and print the resource/timing
//! report — the paper's core flow in ~30 lines.
//!
//!     make artifacts                      # once (trains + exports)
//!     cargo run --release --example quickstart

use dwn::config::Artifacts;
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, Variant};
use dwn::techmap::MapConfig;
use dwn::timing::{analyze, DelayModel};

fn main() -> anyhow::Result<()> {
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");

    // 1. Load the trained sm-50 model (thresholds, mapping, truth tables).
    let model = DwnModel::load(&artifacts.model_path("sm-50"))?;
    println!(
        "model {}: {} LUT6s, PEN+FT accuracy {:.1}% at {}-bit inputs",
        model.name,
        model.num_luts,
        model.penft.acc * 100.0,
        model.penft.frac_bits.unwrap()
    );

    // 2. Generate the full accelerator (encoders + LUT layer + popcount +
    //    argmax) for both variants and compare — the paper's Table I story.
    for variant in [Variant::Ten, Variant::PenFt] {
        let accel = build_accelerator(&model, &AccelOptions::new(variant))?;
        let netlist = accel.map(&MapConfig::default());
        let report = analyze(&netlist, &DelayModel::default());
        println!(
            "  {:7}  {:5} LUTs  {:5} FFs  Fmax {:6.1} MHz  latency {:4.1} ns  AxD {:8.1}",
            variant.label(),
            report.luts,
            report.ffs,
            report.fmax_mhz,
            report.latency_ns,
            report.area_delay
        );
    }

    // 3. The headline: how much does explicit thermometer encoding cost?
    let ten = analyze(
        &build_accelerator(&model, &AccelOptions::new(Variant::Ten))?.map(&MapConfig::default()),
        &DelayModel::default(),
    );
    let pen = analyze(
        &build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?.map(&MapConfig::default()),
        &DelayModel::default(),
    );
    println!(
        "thermometer encoding overhead: {:.2}x LUTs (paper reports up to 3.20x after FT)",
        pen.luts as f64 / ten.luts as f64
    );
    Ok(())
}

//! Serving scenario: stand up the batching coordinator over the JSC
//! classifier and drive it with an open-loop Poisson-ish arrival process at
//! several request rates, reporting latency percentiles vs throughput — the
//! classic serving curve.
//!
//! Backends: `pjrt` (AOT-compiled golden model), `netlist` (bit-accurate
//! interpreter of the generated hardware), `compiled` (the netlist compiled
//! into the wide/parallel execution engine — see DESIGN.md §engine).
//!
//!     cargo run --release --example serve_jsc -- \
//!         [--model sm-50] [--backend pjrt|netlist|compiled] [--lanes 256] [--threads N]

use dwn::config::{Args, Artifacts};
use dwn::coordinator::{Backend, Server, ServerConfig};
use dwn::data::Dataset;
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, Variant};
use dwn::runtime::Engine;
use dwn::techmap::MapConfig;
use dwn::util::SplitMix64;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");
    let name = args.get_or("model", "sm-50");
    let backend = args.get_or("backend", "pjrt");
    let model = DwnModel::load(&artifacts.model_path(&name))?;
    let test = Dataset::load_csv(&artifacts.dataset_path("test"))?;

    let cfg = |max_batch: usize| ServerConfig {
        max_batch,
        max_wait: Duration::from_micros(300),
        queue_depth: 4096,
    };
    let server = match backend.as_str() {
        "pjrt" => {
            let batch = artifacts.hlo_batch()?;
            let hlo = artifacts.hlo_path(&name);
            let (features, classes) = (model.num_features, model.num_classes);
            let server = Server::start_with(
                move || Ok(Backend::Pjrt(Engine::load(&hlo, batch, features, classes)?)),
                cfg(batch),
            )?;
            println!("serving {name} via PJRT (batch {batch})");
            server
        }
        "netlist" => {
            let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
            let nl = accel.map(&MapConfig::default());
            println!("serving {name} via netlist interpreter ({} LUTs)", nl.lut_count());
            Server::start_netlist(
                nl,
                model.penft.frac_bits.expect("penft bits"),
                model.num_features,
                model.num_classes,
                accel.index_width(),
                cfg(512),
            )
        }
        "compiled" => {
            let lanes = args.get_usize("lanes", 256)?;
            let threads = args.get_usize(
                "threads",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            )?;
            let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
            let (nl, tags) = accel.map_with_stages(&MapConfig::default());
            let plan = dwn::engine::compile_with_stages(&nl, Some(&tags));
            println!(
                "serving {name} via compiled engine ({} ops / {} levels, {lanes} lanes x {threads} threads)",
                plan.ops.len(),
                plan.depth()
            );
            let max_batch = lanes * threads.max(1);
            Server::start_compiled(
                plan,
                model.penft.frac_bits.expect("penft bits"),
                model.num_features,
                model.num_classes,
                accel.index_width(),
                lanes,
                threads,
                cfg(max_batch),
            )
        }
        other => anyhow::bail!("unknown backend '{other}' (pjrt|netlist|compiled)"),
    };
    println!("{:>12} {:>12} {:>10} {:>10} {:>10} {:>11}", "target req/s", "achieved", "p50 us", "p99 us", "max us", "mean batch");

    let mut rng = SplitMix64::new(42);
    for target_rps in [2_000u64, 10_000, 50_000, 200_000] {
        let duration = Duration::from_millis(800);
        let t0 = Instant::now();
        let mut sent = 0u64;
        let mut pending = Vec::new();
        // Open-loop arrivals with exponential inter-arrival times.
        let mut next_t = 0f64;
        while t0.elapsed() < duration {
            let now = t0.elapsed().as_secs_f64();
            if now >= next_t {
                let i = (sent as usize) % test.len();
                if let Ok(rx) = server.submit(test.row(i)) {
                    pending.push(rx);
                }
                sent += 1;
                // exponential gap
                let u: f64 = rng.next_f64().max(1e-12);
                next_t += -u.ln() / target_rps as f64;
            } else {
                std::hint::spin_loop();
            }
            if pending.len() >= 2048 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in pending.drain(..) {
            let _ = rx.recv();
        }
        let achieved = sent as f64 / t0.elapsed().as_secs_f64();
        let snap = server.metrics.snapshot();
        println!(
            "{:>12} {:>12.0} {:>10} {:>10} {:>10} {:>11.1}",
            target_rps, achieved, snap.p50_us, snap.p99_us, snap.max_us, snap.mean_batch
        );
    }
    Ok(())
}

//! Serving scenario: stand up the batching coordinator over the JSC
//! classifier and drive it with an open-loop Poisson-ish arrival process at
//! several request rates, reporting latency percentiles vs throughput — the
//! classic serving curve.
//!
//! Backends: `pjrt` (AOT-compiled golden model), `netlist` (bit-accurate
//! interpreter of the generated hardware), `compiled` (the netlist compiled
//! into the wide/parallel execution engine — see DESIGN.md §engine). The
//! compiled backend takes `--head native|lut` and `--tail native|lut`
//! (both default native): a native head computes the thermometer encoding
//! arithmetically (no input bit-packing), a native tail evaluates
//! popcount/argmax arithmetically — both behind the persistent worker pool;
//! lut emulates the corresponding stages of the mapped netlist. It also
//! takes `--engine interp|pool|fused` (default pool), selecting the
//! execution backend from `engine::backend::registry()` — `fused` batches
//! each level's ops by canonical truth table for per-table dispatch.
//!
//! Runs without trained artifacts too (netlist/compiled backends only): a
//! synthetic JSC-sized model stands in, which is what the CI smoke step
//! exercises across the head×tail matrix.
//!
//! `--metrics-every S` prints a one-line *interval* metrics brief every S
//! seconds (what happened since the previous line — `Snapshot::delta`);
//! the final report is always the per-stage latency table (queue-wait →
//! batch-form → head-pack → lut-exec → tail → reply) plus shed count, mean
//! batch size, and the drainer-overlap ratio.
//!
//! `--trace-sample N` traces 1 in N admitted requests through the flight
//! recorder; `--trace-out FILE` writes it as Chrome trace-event JSON after
//! the sweep (DESIGN.md §tracing).
//!
//!     cargo run --release --example serve_jsc -- \
//!         [--model sm-50] [--backend pjrt|netlist|compiled] [--lanes 256] \
//!         [--threads N] [--head native|lut] [--tail native|lut] \
//!         [--engine interp|pool|fused] [--metrics-every S] \
//!         [--trace-sample N] [--trace-out FILE] [--smoke]

use dwn::config::{Args, Artifacts};
use dwn::coordinator::{AdmissionPolicy, Backend, Row, Server, ServerConfig};
use dwn::data::Dataset;
use dwn::engine::backend::{self as eval_backend, CompileModes, CompiledModel};
use dwn::engine::{HeadMode, TailMode};
use dwn::hwgen::{build_accelerator, AccelOptions};
use dwn::model::{DwnModel, SynthSpec, Variant};
use dwn::runtime::Engine;
use dwn::techmap::MapConfig;
use dwn::util::SplitMix64;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["smoke"])?;
    let artifacts = Artifacts::discover();
    let name = args.get_or("model", "sm-50");
    let backend = args.get_or("backend", "pjrt");
    let smoke = args.has_flag("smoke");

    // Trained model + real test rows when artifacts exist; synthetic
    // stand-ins otherwise (same shapes, structural throughput only).
    // Rows are admitted once into shared `Row`s; the open-loop driver below
    // resubmits the same allocations for the whole run (zero-copy serving).
    let (model, rows) = if artifacts.exists() {
        let model = DwnModel::load(&artifacts.model_path(&name))?;
        let test = Dataset::load_csv(&artifacts.dataset_path("test"))?;
        let rows: Vec<Row> = (0..test.len()).map(|i| Row::real(test.row(i))).collect();
        (model, rows)
    } else {
        anyhow::ensure!(
            backend != "pjrt",
            "pjrt backend needs trained artifacts; run `make artifacts` first"
        );
        let spec = SynthSpec::jsc_sized();
        println!("no artifacts; serving synthetic model {}", spec.name);
        let model = DwnModel::synthetic(&spec);
        let mut rng = SplitMix64::new(0x5EED);
        let rows: Vec<Row> = (0..2048)
            .map(|_| {
                Row::from(
                    (0..model.num_features)
                        .map(|_| (2.0 * rng.next_f64() - 1.0) as f32)
                        .collect::<Vec<f32>>(),
                )
            })
            .collect();
        (model, rows)
    };

    let cfg = |max_batch: usize| ServerConfig {
        max_batch,
        max_wait: Duration::from_micros(300),
        queue_depth: 4096,
        admission: AdmissionPolicy::Shed,
        ..ServerConfig::default()
    };
    let server = match backend.as_str() {
        "pjrt" => {
            let batch = artifacts.hlo_batch()?;
            let hlo = artifacts.hlo_path(&name);
            let (features, classes) = (model.num_features, model.num_classes);
            let server = Server::start_with(
                move || Ok(Backend::Pjrt(Engine::load(&hlo, batch, features, classes)?)),
                cfg(batch),
            )?;
            println!("serving {name} via PJRT (batch {batch})");
            server
        }
        "netlist" => {
            let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
            let nl = accel.map(&MapConfig::default());
            println!("serving {} via netlist interpreter ({} LUTs)", model.name, nl.lut_count());
            Server::start_netlist(
                nl,
                model.penft.frac_bits.expect("penft bits"),
                model.num_features,
                model.num_classes,
                accel.index_width(),
                cfg(512),
            )
        }
        "compiled" => {
            let lanes = args.get_usize("lanes", 256)?;
            let threads = args.get_usize(
                "threads",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            )?;
            let head_mode: HeadMode = args.get_parse("head", HeadMode::Native)?;
            let tail_mode: TailMode = args.get_parse("tail", TailMode::Native)?;
            // Execution backend from the registry: `pool` (per-op dispatch),
            // `fused` (per-table dispatch), or `interp` for completeness.
            let engine_name = args.get_or("engine", "pool");
            let engine = eval_backend::by_name(&engine_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown engine '{engine_name}' (available: {})",
                    eval_backend::names().join("|")
                )
            })?;
            let accel = build_accelerator(&model, &AccelOptions::new(Variant::PenFt))?;
            let (nl, tags, head, tail) = accel.map_with_head(&MapConfig::default());
            let modes = CompileModes {
                tags: Some(&tags),
                head: head.as_ref(),
                tail: tail.as_ref(),
                head_mode,
                tail_mode,
                frac_bits: model.penft.frac_bits.expect("penft bits"),
                num_features: model.num_features,
                num_classes: model.num_classes,
                index_width: accel.index_width(),
                lanes,
                threads,
            };
            let compiled: Box<dyn CompiledModel> =
                engine.compile(&nl, &modes, dwn::engine::OptLevel::None);
            if let Some(plan) = compiled.plan() {
                if head_mode == HeadMode::Native && plan.head.is_none() {
                    println!("note: head metadata unavailable; fell back to LUT emulation");
                }
                if tail_mode == TailMode::Native && plan.tail.is_none() {
                    println!("note: tail metadata unavailable; fell back to LUT emulation");
                }
                println!(
                    "serving {} via {} engine ({} ops / {} levels, {lanes} lanes x {threads} threads, {} head, {} tail)",
                    model.name,
                    engine.name(),
                    plan.ops.len(),
                    plan.depth(),
                    if plan.head.is_some() { "native" } else { "lut" },
                    if plan.tail.is_some() { "native" } else { "lut" }
                );
            } else {
                println!(
                    "serving {} via {} engine ({} LUTs interpreted)",
                    model.name,
                    engine.name(),
                    nl.lut_count()
                );
            }
            let max_batch = compiled.max_batch_hint();
            Server::start_model(compiled, cfg(max_batch))
        }
        other => anyhow::bail!("unknown backend '{other}' (pjrt|netlist|compiled)"),
    };
    // Sampled request tracing into the always-on flight recorder; the
    // recorder also auto-dumps on latency anomalies and shed bursts.
    let trace_sample = args.get_usize("trace-sample", 0)?;
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let tracer = if trace_sample > 0 || trace_out.is_some() {
        Some(server.enable_tracing(dwn::telemetry::TraceConfig {
            sample: trace_sample.max(1) as u32,
            out: trace_out.clone(),
            ..Default::default()
        }))
    } else {
        None
    };
    let metrics_every = args.get_usize("metrics-every", 0)?;
    let _reporter = if metrics_every > 0 {
        let metrics = server.metrics.clone();
        // Interval brief: delta against the previous tick's snapshot.
        let mut prev = metrics.snapshot();
        Some(dwn::telemetry::Reporter::spawn(
            Duration::from_secs(metrics_every as u64),
            move || {
                let now = metrics.snapshot();
                println!("[metrics] {}", now.delta(&prev).render_brief());
                prev = now;
            },
        ))
    } else {
        None
    };
    println!("{:>12} {:>12} {:>10} {:>10} {:>10} {:>11} {:>9}", "target req/s", "achieved", "p50 us", "p99 us", "max us", "mean batch", "shed");

    let rates: &[u64] =
        if smoke { &[10_000, 100_000] } else { &[2_000, 10_000, 50_000, 200_000] };
    let duration = Duration::from_millis(if smoke { 200 } else { 800 });
    let mut rng = SplitMix64::new(42);
    for &target_rps in rates {
        let t0 = Instant::now();
        let mut sent = 0u64;
        let mut pending = Vec::new();
        // Open-loop arrivals with exponential inter-arrival times.
        let mut next_t = 0f64;
        while t0.elapsed() < duration {
            let now = t0.elapsed().as_secs_f64();
            if now >= next_t {
                let i = (sent as usize) % rows.len();
                // Resubmitting a cached Row is a refcount bump. Sheds are
                // typed, counted in the metrics, and expected under
                // overload; anything else (e.g. a stopped server) is fatal.
                match server.submit_row(rows[i].clone()) {
                    Ok(rx) => pending.push(rx),
                    Err(e) if e.is_backpressure() => {}
                    Err(e) => anyhow::bail!("serving stopped mid-run: {e}"),
                }
                sent += 1;
                // exponential gap
                let u: f64 = rng.next_f64().max(1e-12);
                next_t += -u.ln() / target_rps as f64;
            } else {
                std::hint::spin_loop();
            }
            if pending.len() >= 2048 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in pending.drain(..) {
            let _ = rx.recv();
        }
        let achieved = sent as f64 / t0.elapsed().as_secs_f64();
        let snap = server.metrics.snapshot();
        println!(
            "{:>12} {:>12.0} {:>10} {:>10} {:>10} {:>11.1} {:>9}",
            target_rps,
            achieved,
            snap.p50_us,
            snap.p99_us,
            snap.max_us,
            snap.mean_batch,
            snap.rejected
        );
    }
    // Final request-path report over the whole sweep: per-stage percentiles
    // plus the shed / batch-size / drainer-overlap counters.
    println!("\nfinal request-path report:");
    println!("{}", server.metrics.snapshot().render_table());
    if let (Some(tracer), Some(path)) = (&tracer, &trace_out) {
        tracer.dump_to(path)?;
        let st = tracer.stats();
        println!(
            "wrote Chrome trace to {} ({} requests traced, {} ring events, {} anomaly dumps)",
            path.display(),
            st.sampled,
            st.ring_events,
            st.dumps.saturating_sub(1)
        );
    }
    Ok(())
}

//! Serving scenario: stand up the batching coordinator over the AOT-compiled
//! DWN model (PJRT backend) and drive it with an open-loop Poisson-ish
//! arrival process at several request rates, reporting latency percentiles
//! vs throughput — the classic serving curve, here for the JSC classifier.
//!
//!     cargo run --release --example serve_jsc [-- --model sm-50]

use dwn::config::{Args, Artifacts};
use dwn::coordinator::{Backend, Server, ServerConfig};
use dwn::data::Dataset;
use dwn::model::DwnModel;
use dwn::runtime::Engine;
use dwn::util::SplitMix64;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let artifacts = Artifacts::discover();
    anyhow::ensure!(artifacts.exists(), "run `make artifacts` first");
    let name = args.get_or("model", "sm-50");
    let model = DwnModel::load(&artifacts.model_path(&name))?;
    let test = Dataset::load_csv(&artifacts.dataset_path("test"))?;

    let batch = artifacts.hlo_batch()?;
    let hlo = artifacts.hlo_path(&name);
    let (features, classes) = (model.num_features, model.num_classes);
    let server = Server::start_with(
        move || Ok(Backend::Pjrt(Engine::load(&hlo, batch, features, classes)?)),
        ServerConfig {
            max_batch: batch,
            max_wait: Duration::from_micros(300),
            queue_depth: 4096,
        },
    )?;
    println!("serving {} via PJRT (batch {batch})", name);
    println!("{:>12} {:>12} {:>10} {:>10} {:>10} {:>11}", "target req/s", "achieved", "p50 us", "p99 us", "max us", "mean batch");

    let mut rng = SplitMix64::new(42);
    for target_rps in [2_000u64, 10_000, 50_000, 200_000] {
        let duration = Duration::from_millis(800);
        let t0 = Instant::now();
        let mut sent = 0u64;
        let mut pending = Vec::new();
        // Open-loop arrivals with exponential inter-arrival times.
        let mut next_t = 0f64;
        while t0.elapsed() < duration {
            let now = t0.elapsed().as_secs_f64();
            if now >= next_t {
                let i = (sent as usize) % test.len();
                if let Ok(rx) = server.submit(test.row(i)) {
                    pending.push(rx);
                }
                sent += 1;
                // exponential gap
                let u: f64 = rng.next_f64().max(1e-12);
                next_t += -u.ln() / target_rps as f64;
            } else {
                std::hint::spin_loop();
            }
            if pending.len() >= 2048 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in pending.drain(..) {
            let _ = rx.recv();
        }
        let achieved = sent as f64 / t0.elapsed().as_secs_f64();
        let snap = server.metrics.snapshot();
        println!(
            "{:>12} {:>12.0} {:>10} {:>10} {:>10} {:>11.1}",
            target_rps, achieved, snap.p50_us, snap.p99_us, snap.max_us, snap.mean_batch
        );
    }
    Ok(())
}

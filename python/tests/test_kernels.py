"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py), swept
over shapes/dtypes with hypothesis — the core correctness signal."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
from compile.kernels.lut_layer import lut_layer
from compile.kernels.popcount import popcount
from compile.kernels.thermometer import thermometer_encode


def rand_case(rng, batch, features, tbits, luts, k):
    x = rng.uniform(-1, 1, size=(batch, features)).astype(np.float32)
    th = np.sort(rng.uniform(-1, 1, size=(features, tbits)).astype(np.float32), axis=1)
    sel = rng.integers(0, features * tbits, size=(luts, k)).astype(np.int32)
    tables = rng.integers(0, 2, size=(luts, 1 << k)).astype(np.float32)
    return x, th, sel, tables


@given(
    batch=st.sampled_from([1, 3, 64, 128, 130]),
    features=st.integers(1, 8),
    tbits=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_thermometer_kernel_matches_ref(batch, features, tbits, seed):
    rng = np.random.default_rng(seed)
    x, th, _, _ = rand_case(rng, batch, features, tbits, 1, 2)
    got = np.asarray(thermometer_encode(jnp.asarray(x), jnp.asarray(th)))
    want = np.asarray(kref.encode_ref(jnp.asarray(x), jnp.asarray(th)))
    np.testing.assert_array_equal(got, want)


@given(
    batch=st.sampled_from([1, 5, 64, 128]),
    luts=st.integers(1, 30),
    k=st.integers(1, 6),
    nbits=st.integers(2, 64),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_lut_layer_kernel_matches_ref(batch, luts, k, nbits, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(batch, nbits)).astype(np.float32)
    sel = rng.integers(0, nbits, size=(luts, k)).astype(np.int32)
    tables = rng.integers(0, 2, size=(luts, 1 << k)).astype(np.float32)
    got = np.asarray(lut_layer(jnp.asarray(bits), jnp.asarray(sel), jnp.asarray(tables)))
    want = np.asarray(kref.lut_layer_ref(jnp.asarray(bits), jnp.asarray(sel), jnp.asarray(tables)))
    np.testing.assert_array_equal(got, want)


@given(
    batch=st.sampled_from([1, 7, 64, 128]),
    classes=st.integers(2, 8),
    group=st.integers(1, 12),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=20, deadline=None)
def test_popcount_kernel_matches_ref(batch, classes, group, seed):
    rng = np.random.default_rng(seed)
    outs = rng.integers(0, 2, size=(batch, classes * group)).astype(np.float32)
    got = np.asarray(popcount(jnp.asarray(outs), classes))
    want = np.asarray(kref.popcount_ref(jnp.asarray(outs), classes))
    np.testing.assert_array_equal(got, want)


def test_full_forward_composes():
    rng = np.random.default_rng(42)
    x, th, sel, tables = rand_case(rng, 64, 4, 8, 10, 6)
    from compile import model

    s_pl, p_pl = model.hard_forward(
        jnp.asarray(x), jnp.asarray(th), jnp.asarray(sel), jnp.asarray(tables), 5
    )
    s_ref, p_ref = model.hard_forward(
        jnp.asarray(x), jnp.asarray(th), jnp.asarray(sel), jnp.asarray(tables), 5, use_ref=True
    )
    np.testing.assert_array_equal(np.asarray(s_pl), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(p_pl), np.asarray(p_ref))


def test_argmax_tie_breaks_low():
    scores = jnp.asarray(np.array([[3, 5, 5, 1, 5]], dtype=np.int32))
    assert int(kref.argmax_ref(scores)[0]) == 1

"""Unit tests for thermometer encoding + fixed-point quantization."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import encoding


def test_distributive_thresholds_are_quantiles():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(5000, 3)).astype(np.float32)
    th = encoding.distributive_thresholds(x, 9)
    assert th.shape == (3, 9)
    # middle threshold ~ median
    assert np.allclose(th[:, 4], np.median(x, axis=0), atol=0.05)
    # sorted ascending
    assert (np.diff(th, axis=1) >= 0).all()


def test_uniform_thresholds_evenly_spaced():
    th = encoding.uniform_thresholds(2, 7)
    diffs = np.diff(th[0])
    assert np.allclose(diffs, diffs[0])
    assert th[0][0] > -1.0 and th[0][-1] < 1.0
    assert np.allclose(th[0], th[1])


def test_encode_is_thermometer():
    th = np.array([[-0.5, 0.0, 0.5]], dtype=np.float32)
    x = np.array([[-0.7], [-0.2], [0.2], [0.9]], dtype=np.float32)
    bits = np.asarray(encoding.encode(jnp.asarray(x), jnp.asarray(th)))
    assert bits.tolist() == [[0, 0, 0], [1, 0, 0], [1, 1, 0], [1, 1, 1]]


@given(st.lists(st.floats(-1, 0.999), min_size=1, max_size=20), st.integers(2, 10))
@settings(max_examples=30, deadline=None)
def test_encode_monotone_in_levels(values, bits):
    """A thermometer code never has a 1 above a 0 (w.r.t. sorted thresholds)."""
    th = encoding.uniform_thresholds(1, bits)
    x = np.array([[v] for v in values], dtype=np.float32)
    enc = np.asarray(encoding.encode(jnp.asarray(x), jnp.asarray(th)))
    for row in enc:
        # once it drops to 0 it must stay 0
        seen_zero = False
        for b in row:
            if b == 0:
                seen_zero = True
            assert not (seen_zero and b == 1)


@given(st.floats(-2, 2), st.integers(2, 12))
@settings(max_examples=100, deadline=None)
def test_quantize_inputs_on_grid(x, n):
    q = encoding.quantize_inputs(np.array([[x]], dtype=np.float32), n)[0, 0]
    scale = 1 << n
    k = round(float(q) * scale)
    assert abs(k / scale - q) < 1e-6
    assert -1.0 <= q <= 1.0 - 1.0 / scale + 1e-9


@given(st.floats(-1, 1), st.integers(2, 12))
@settings(max_examples=100, deadline=None)
def test_threshold_int_roundtrip(t, n):
    tq = encoding.quantize_thresholds(np.array([[t]], dtype=np.float32), n)[0, 0]
    ti = encoding.threshold_ints(np.array([[tq]], dtype=np.float32), n)[0, 0]
    assert abs(ti / (1 << n) - tq) < 1e-6
    assert -(1 << n) <= ti <= (1 << n) - 1


def test_soft_encode_approaches_hard():
    th = np.array([[-0.5, 0.0, 0.5]], dtype=np.float32)
    x = np.array([[0.2]], dtype=np.float32)
    hard = np.asarray(encoding.encode(jnp.asarray(x), jnp.asarray(th)))
    soft = np.asarray(encoding.encode_soft(jnp.asarray(x), jnp.asarray(th), tau=1e-4))
    assert np.allclose(hard, soft, atol=1e-3)

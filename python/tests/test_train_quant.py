"""Training + PTQ/fine-tune smoke tests (small but real)."""

import numpy as np
import jax.numpy as jnp

from compile import data, encoding, model, quantize, train


def setup_small():
    xt, yt, xe, ye = data.load_jsc(1500, 400)
    cfg = model.DwnConfig("t", num_luts=10, thermo_bits=16)
    th = encoding.distributive_thresholds(xt, cfg.thermo_bits)
    return cfg, xt, yt, xe, ye, th


def test_training_reduces_loss_and_beats_chance():
    cfg, xt, yt, xe, ye, th = setup_small()
    p, hist = train.train(cfg, xt, yt, xe, ye, th, steps=80, batch=64, log_every=20)
    acc = train.evaluate_hard(p, xe, ye, th, cfg, max_n=400)
    assert acc > 0.35, f"must beat 20% chance clearly, got {acc}"
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_step_lr_schedule():
    assert train.step_lr(0.1, 0, 30, 0.1) == 0.1
    assert abs(train.step_lr(0.1, 30, 30, 0.1) - 0.01) < 1e-12
    assert abs(train.step_lr(0.1, 65, 30, 0.1) - 0.001) < 1e-12


def test_adam_converges_quadratic():
    p = {"x": jnp.asarray(5.0)}
    opt = train.adam_init(p)
    for _ in range(300):
        g = {"x": 2.0 * p["x"]}
        p, opt = train.adam_step(p, g, opt, lr=0.1)
    assert abs(float(p["x"])) < 0.05


def test_ptq_monotone_band():
    """Quantized accuracy at high bit-width ~= float accuracy."""
    cfg, xt, yt, xe, ye, th = setup_small()
    p, _ = train.train(cfg, xt, yt, xe, ye, th, steps=60, batch=64, verbose=False)
    base = train.evaluate_hard(p, xe, ye, th, cfg, max_n=400)
    acc12 = quantize.quantized_accuracy(p, th, 12, xe, ye, cfg, max_n=400)
    assert abs(acc12 - base) < 0.03
    # Very coarse quantization should (usually) hurt; accept no-gain too.
    acc2 = quantize.quantized_accuracy(p, th, 2, xe, ye, cfg, max_n=400)
    assert acc2 <= base + 0.05


def test_fine_tune_runs_and_freezes_thresholds():
    cfg, xt, yt, xe, ye, th = setup_small()
    p, _ = train.train(cfg, xt, yt, xe, ye, th, steps=40, batch=64, verbose=False)
    ftp, th_q, acc = quantize.fine_tune(p, th, 4, cfg, xt, yt, xe, ye, steps=20)
    # thresholds stayed on the (1,4) grid
    k = np.round(th_q * 16)
    assert np.allclose(th_q, k / 16, atol=1e-6)
    assert 0.0 <= acc <= 1.0
    # parameters actually changed
    assert not np.allclose(np.asarray(p["theta"]), np.asarray(ftp["theta"]))

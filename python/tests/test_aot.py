"""AOT export contract tests: HLO text format, table hex encoding, golden
CSV consistency. Uses tiny in-memory models (no training)."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, encoding, model
from compile.kernels import ref as kref


def tiny_design(seed=0):
    rng = np.random.default_rng(seed)
    th = np.sort(rng.uniform(-1, 1, size=(16, 8)).astype(np.float32), axis=1)
    sel = rng.integers(0, 16 * 8, size=(10, 6)).astype(np.int32)
    tables = rng.integers(0, 2, size=(10, 64)).astype(np.float32)
    return th, sel, tables


def test_tables_to_hex_roundtrip():
    rng = np.random.default_rng(1)
    tables = rng.integers(0, 2, size=(5, 64)).astype(np.float32)
    hexes = aot.tables_to_hex(tables)
    for row, h in zip(tables, hexes):
        mask = int(h, 16)
        for i in range(64):
            assert ((mask >> i) & 1) == int(row[i])


def test_export_hlo_contains_constants(tmp_path):
    """The exported text must carry full constants — xla_extension 0.5.1
    parses `{...}` placeholders as zeros (the bug this guards against)."""
    th, sel, tables = tiny_design()
    p = tmp_path / "t.hlo.txt"
    n = aot.export_hlo(str(p), th, sel, tables, 5)
    text = p.read_text()
    assert n == len(text)
    assert "ENTRY" in text
    assert "{...}" not in text, "large constants must be printed"


def test_golden_pen_matches_ref(tmp_path):
    th, sel, tables = tiny_design()
    rng = np.random.default_rng(2)
    x = rng.uniform(-1, 1, size=(32, 16)).astype(np.float32)
    y = rng.integers(0, 5, size=32)
    bw = 5
    th_q = encoding.quantize_thresholds(th, bw)
    p = tmp_path / "g.csv"
    aot.export_golden_pen(str(p), x, y, th_q, bw, sel, tables, 5, n=32)
    lines = p.read_text().strip().split("\n")
    assert lines[0].startswith(f"# frac_bits={bw}")
    assert len(lines) == 34
    # re-derive the first row and compare
    row = [int(v) for v in lines[2].split(",")]
    x_q = encoding.quantize_inputs(x[:1], bw)
    scores, pred = kref.dwn_forward_ref(
        jnp.asarray(x_q), jnp.asarray(th_q), jnp.asarray(sel), jnp.asarray(tables), 5
    )
    xi = encoding.input_ints(x[:1], bw)
    assert row[:16] == xi[0].tolist()
    assert row[16:21] == np.asarray(scores)[0].tolist()
    assert row[21] == int(pred[0])


def test_golden_ten_hex_width(tmp_path):
    th, sel, tables = tiny_design()
    rng = np.random.default_rng(3)
    x = rng.uniform(-1, 1, size=(8, 16)).astype(np.float32)
    y = rng.integers(0, 5, size=8)
    p = tmp_path / "t.csv"
    aot.export_golden_ten(str(p), x, y, th, sel, tables, 5, n=8)
    lines = p.read_text().strip().split("\n")
    used = int(lines[0].split("used_bits=")[1])
    hexlen = (used + 3) // 4
    for line in lines[2:]:
        assert len(line.split(",")[0]) == hexlen


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="full artifacts not built",
)
def test_manifest_consistent_with_models():
    import json

    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(f"{root}/manifest.json") as f:
        manifest = json.load(f)
    assert manifest["hlo_batch"] == aot.HLO_BATCH
    for c in manifest["configs"]:
        with open(f"{root}/{c['model']}") as f:
            mj = json.load(f)
        assert mj["name"] == c["name"]
        assert abs(mj["variants"]["penft"]["acc"] - c["acc_penft"]) < 1e-9
        assert os.path.exists(f"{root}/{c['hlo_penft']}")

"""Synthetic JSC generator invariants (mirrored in rust/src/data/synth.rs)."""

import numpy as np

from compile import data


def test_splitmix_reference_values():
    """First value of the seed-0 stream — cross-checked with the rust mirror."""
    r = data.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF


def test_generate_deterministic():
    x1, y1 = data.generate_raw(50, seed=123)
    x2, y2 = data.generate_raw(50, seed=123)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_labels_valid_and_roughly_balanced():
    _, y = data.generate_raw(5000)
    counts = np.bincount(y, minlength=5)
    assert counts.min() > 700


def test_normalized_range():
    xt, yt, xe, ye = data.load_jsc(2000, 500)
    assert xt.shape == (2000, 16)
    assert xe.shape == (500, 16)
    assert xt.min() >= -1.0 and xt.max() <= 1.0
    assert xe.min() >= -1.0 - 1e-6


def test_classes_2_3_overlap_more_than_typical():
    """W/Z (classes 2, 3) are designed to overlap: their class-mean distance
    must be well below the typical pair distance (the style nonlinearities
    distort absolute distances, so we don't require the strict minimum)."""
    x, y = data.generate_raw(20000)
    means = np.stack([x[y == c].mean(axis=0) for c in range(5)])
    d = np.linalg.norm(means[:, None] - means[None, :], axis=-1)
    pairs = [d[i, j] for i in range(5) for j in range(i + 1, 5)]
    assert d[2, 3] < np.median(pairs), f"d23={d[2, 3]:.3f} pairs={sorted(pairs)}"


def test_csv_roundtrip(tmp_path):
    xt, yt, _, _ = data.load_jsc(100, 10)
    p = tmp_path / "d.csv"
    data.to_csv(str(p), xt, yt)
    lines = p.read_text().strip().split("\n")
    assert len(lines) == 101
    assert lines[0].endswith(",label")
    first = lines[1].split(",")
    assert len(first) == 17
    np.testing.assert_allclose(float(first[0]), xt[0, 0], atol=1e-6)

"""L2 model tests: shapes, multilinear LUT relaxation, mapping export."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import encoding, model


def small_cfg():
    return model.DwnConfig("t", num_luts=10, thermo_bits=8, num_features=4)


def test_init_shapes():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    assert p["w"].shape == (cfg.pins, cfg.num_bits)
    assert p["theta"].shape == (cfg.num_luts, 64)


def test_soft_forward_shapes_and_grads():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    x = rng.uniform(-1, 1, size=(16, 4)).astype(np.float32)
    th = encoding.distributive_thresholds(x, cfg.thermo_bits)

    def loss(params):
        logits = model.soft_forward(params, jnp.asarray(x), jnp.asarray(th), cfg)
        assert logits.shape == (16, cfg.num_classes)
        return jnp.mean(logits**2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["theta"]).sum()) > 0, "theta must receive gradient"
    assert float(jnp.abs(g["w"]).sum()) > 0, "mapping must receive gradient"


def test_multilinear_matches_hard_lut_at_corners():
    """At binary (0/1) soft bits, the multilinear LUT equals table lookup."""
    cfg = model.DwnConfig("t", num_luts=1, thermo_bits=8, num_features=1)
    key = jax.random.PRNGKey(3)
    theta = jax.random.normal(key, (1, 64))
    for addr in [0, 1, 17, 63]:
        s = jnp.asarray(
            np.array([[(addr >> j) & 1 for j in range(6)]], dtype=np.float32)
        ).reshape(1, 1, 6)
        v = model._multilinear_lut(theta, s)
        assert np.allclose(float(v[0, 0]), float(theta[0, addr]), atol=1e-5), f"addr={addr}"


def test_hard_mapping_shape_and_range():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    sel = np.asarray(model.hard_mapping(p["w"]))
    assert sel.shape == (cfg.num_luts, cfg.lut_k)
    assert sel.min() >= 0 and sel.max() < cfg.num_bits


def test_binarize_tables():
    theta = np.array([[-0.5, 0.0, 0.2, -0.1]])
    t = model.binarize_tables(theta)
    assert t.tolist() == [[0.0, 1.0, 1.0, 0.0]]


def test_used_bits_unique_sorted():
    sel = np.array([[3, 1, 3], [2, 1, 7]])
    u = model.used_bits(sel)
    assert u.tolist() == [1, 2, 3, 7]


def test_hard_accuracy_bounds():
    cfg = small_cfg()
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    x = rng.uniform(-1, 1, size=(100, 4)).astype(np.float32)
    y = rng.integers(0, 5, size=100)
    th = encoding.distributive_thresholds(x, cfg.thermo_bits)
    sel = np.asarray(model.hard_mapping(p["w"]))
    tables = model.binarize_tables(p["theta"])
    acc = model.hard_accuracy(x, y, jnp.asarray(th), jnp.asarray(sel), jnp.asarray(tables))
    assert 0.0 <= acc <= 1.0

"""LogicNets-lite: quantizer, forward, and truth-table enumeration."""

import numpy as np
import jax.numpy as jnp

from compile import data, logicnets


def small_cfg():
    return logicnets.LogicNetsConfig("t", hidden=(8,), fanin=3, abits=2, ibits=2, seed=3)


def test_quantize_ste_grid():
    x = jnp.linspace(-1.2, 1.2, 41)
    q = np.asarray(logicnets.quantize_ste(x, 2, -1.0, 1.0))
    codes = logicnets.act_codes(2)
    for v in q:
        assert any(abs(v - c) < 1e-6 for c in codes), f"{v} off-grid"


def test_forward_shapes():
    cfg = small_cfg()
    params, masks = logicnets.init(cfg)
    x = np.random.default_rng(0).uniform(-1, 1, size=(7, 16)).astype(np.float32)
    out = logicnets.forward(params, masks, jnp.asarray(x), cfg)
    assert out.shape == (7, 5)


def test_enumeration_matches_forward():
    """The enumerated truth tables must reproduce the quantized forward pass
    exactly (this is the contract the rust hardware relies on)."""
    cfg = small_cfg()
    params, masks = logicnets.init(cfg)
    rng = np.random.default_rng(1)

    in_codes = logicnets.act_codes(cfg.ibits)
    hid_codes = logicnets.act_codes(cfg.abits)

    # Python-side table walk (mirrors rust predict_codes).
    def predict_via_tables(codes):
        h = list(codes)
        for li, (p, sel) in enumerate(zip(params, masks)):
            is_last = li == len(params) - 1
            codes_in = in_codes if li == 0 else hid_codes
            w = np.asarray(p["w"])
            b = np.asarray(p["b"])
            nxt = []
            scores = []
            n_codes = len(codes_in)
            for n in range(len(w)):
                table = logicnets.enumerate_neuron(w[n], float(b[n]), codes_in, hid_codes, is_last)
                addr = 0
                for j, s in enumerate(sel[n]):
                    addr += int(h[s]) * (n_codes**j)
                v = table[addr]
                (scores if is_last else nxt).append(v)
            if is_last:
                return int(np.argmax(scores))
            h = nxt

    for _ in range(20):
        codes = rng.integers(0, 4, size=16)
        x = np.array([in_codes[c] for c in codes], dtype=np.float32)[None]
        logits = np.asarray(logicnets.forward(params, masks, jnp.asarray(x), cfg))[0]
        want = int(np.argmax(np.round(logits * 1000)))
        got = predict_via_tables(codes)
        assert got == want


def test_training_beats_chance():
    cfg = small_cfg()
    xt, yt, xe, ye = data.load_jsc(2000, 500)
    params, masks = logicnets.train(cfg, xt, yt, xe, ye, steps=60, batch=128, verbose=False)
    acc = logicnets.accuracy(params, masks, xe, ye, cfg)
    assert acc > 0.35, acc

"""Thermometer encodings for DWN inputs.

Two threshold placement schemes (paper Fig. 2):

* **distributive** — percentile-based thresholds (Bacellar et al., ESANN'22):
  threshold i of feature f is the (i+1)/(T+1) quantile of the training
  distribution of feature f. Non-uniform; each threshold needs its own
  comparator in hardware (paper Fig. 3) but yields higher accuracy.
* **uniform** — T evenly spaced thresholds over [-1, 1).

A value x encodes to T bits: bit_i = (x >= t_i). Thresholds are kept sorted
ascending so the code is a valid thermometer (prefix of ones ... actually a
suffix: bits for thresholds below x are 1).

Post-training quantization (paper §III): thresholds are quantized to signed
fixed-point (1, n) — one sign bit, n fractional bits — i.e. integer grid
k / 2^n with k in [-2^n, 2^n - 1]. Inputs are quantized to the same grid
(floor), matching the positional-encoded-number (PEN) hardware interface.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def distributive_thresholds(train_x: np.ndarray, bits: int) -> np.ndarray:
    """Percentile thresholds, shape [F, bits], per feature, sorted ascending.

    train_x: [N, F] training features (already normalised to [-1, 1)).
    """
    qs = (np.arange(bits, dtype=np.float64) + 1.0) / (bits + 1.0)
    th = np.quantile(train_x.astype(np.float64), qs, axis=0).T  # [F, bits]
    return np.sort(th, axis=1).astype(np.float32)


def uniform_thresholds(num_features: int, bits: int) -> np.ndarray:
    """Evenly spaced thresholds over [-1, 1), shape [F, bits]."""
    th = -1.0 + 2.0 * (np.arange(bits, dtype=np.float64) + 1.0) / (bits + 1.0)
    return np.tile(th.astype(np.float32), (num_features, 1))


def encode(x, thresholds):
    """Hard thermometer encoding. x: [B, F]; thresholds: [F, T] -> [B, F*T] in {0,1}."""
    x = jnp.asarray(x)
    th = jnp.asarray(thresholds)
    bits = (x[:, :, None] >= th[None, :, :]).astype(jnp.float32)
    return bits.reshape(x.shape[0], -1)


def encode_soft(x, thresholds, tau: float):
    """Differentiable encoding: sigmoid((x - t)/tau), same shape contract as encode."""
    x = jnp.asarray(x)
    th = jnp.asarray(thresholds)
    bits = _sigmoid((x[:, :, None] - th[None, :, :]) / tau)
    return bits.reshape(x.shape[0], -1)


def _sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def quantize_thresholds(th: np.ndarray, frac_bits: int) -> np.ndarray:
    """Quantize thresholds to signed fixed-point (1, n) — paper §III PTQ.

    Returns float thresholds on the k/2^n grid, k in [-2^n, 2^n - 1].
    """
    scale = float(1 << frac_bits)
    k = np.round(th.astype(np.float64) * scale)
    k = np.clip(k, -scale, scale - 1.0)
    return (k / scale).astype(np.float32)


def threshold_ints(th_q: np.ndarray, frac_bits: int) -> np.ndarray:
    """Integer representation k = t * 2^n of quantized thresholds (int32)."""
    scale = float(1 << frac_bits)
    return np.round(th_q.astype(np.float64) * scale).astype(np.int32)


def quantize_inputs(x: np.ndarray, frac_bits: int) -> np.ndarray:
    """Quantize inputs to the PEN fixed-point grid (floor), staying in [-1, 1)."""
    scale = float(1 << frac_bits)
    k = np.floor(np.asarray(x, dtype=np.float64) * scale)
    k = np.clip(k, -scale, scale - 1.0)
    return (k / scale).astype(np.float32)


def input_ints(x: np.ndarray, frac_bits: int) -> np.ndarray:
    """Integer PEN representation of quantized inputs (int32), k in [-2^n, 2^n-1]."""
    scale = float(1 << frac_bits)
    k = np.floor(np.asarray(x, dtype=np.float64) * scale)
    return np.clip(k, -scale, scale - 1.0).astype(np.int32)

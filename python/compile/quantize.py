"""Post-training quantization + fine-tuning of thermometer thresholds.

Paper §III: thresholds are quantized to signed fixed-point (1, n); n is found
by progressively reducing the fractional bits until the quantized model drops
below its baseline accuracy (PTQ -> **DWN-PEN**). Fine-tuning then recovers
accuracy at still lower n (**DWN-PEN+FT**): thresholds stay frozen on the
quantized grid while LUT contents and mapping are re-trained for a few
epochs (Adam, StepLR as in the paper).
"""

from __future__ import annotations

import numpy as np

from . import encoding, model, train


def quantized_accuracy(params, thresholds, frac_bits, x_test, y_test, cfg, max_n=6000):
    """Hard accuracy with thresholds *and inputs* on the (1, n) grid."""
    th_q = encoding.quantize_thresholds(np.asarray(thresholds), frac_bits)
    x_q = encoding.quantize_inputs(x_test[:max_n], frac_bits)
    import jax.numpy as jnp

    sel = np.asarray(model.hard_mapping(params["w"]))
    tables = model.binarize_tables(params["theta"])
    return model.hard_accuracy(
        x_q, y_test[:max_n], jnp.asarray(th_q), jnp.asarray(sel), jnp.asarray(tables), cfg.num_classes
    )


def ptq_sweep(params, thresholds, x_test, y_test, cfg, baseline_acc, tol=0.002, max_bits=12, min_bits=3):
    """Find the smallest n with acc(n) >= baseline - tol (paper's PTQ rule).

    Returns (best_n, {n: acc}).
    """
    accs = {}
    best = max_bits
    for n in range(max_bits, min_bits - 1, -1):
        acc = quantized_accuracy(params, thresholds, n, x_test, y_test, cfg)
        accs[n] = acc
        if acc >= baseline_acc - tol:
            best = n
        else:
            break
    return best, accs


def fine_tune(params, thresholds, frac_bits, cfg, x_train, y_train, x_test, y_test, steps=120, lr=0.001, verbose=False):
    """PEN+FT: freeze quantized thresholds, re-train LUTs + mapping.

    Training *data* is also quantized to the input grid so the model adapts
    to the PEN interface it will see in hardware.
    """
    th_q = encoding.quantize_thresholds(np.asarray(thresholds), frac_bits)
    x_train_q = encoding.quantize_inputs(x_train, frac_bits)
    x_test_q = encoding.quantize_inputs(x_test, frac_bits)
    ft_params, _ = train.train(
        cfg,
        x_train_q,
        y_train,
        x_test_q,
        y_test,
        th_q,
        steps=steps,
        lr=lr,
        params={k: v for k, v in params.items()},
        lr_step_size=max(1, int(steps * 0.6)),
        log_every=max(1, steps // 2),
        verbose=verbose,
    )
    acc = quantized_accuracy(ft_params, thresholds, frac_bits, x_test, y_test, cfg)
    return ft_params, th_q, acc

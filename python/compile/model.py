"""L2: the DWN model (Bacellar et al. 2024), JAX reimplementation.

Architecture (paper Fig. 1): thermometer encoders -> one LUT layer of L
6-input LUTs -> per-class popcount -> argmax. Two forward paths:

* ``soft_forward`` — differentiable relaxation used for training:
    - soft thermometer bits  sigmoid((x - t)/tau_enc)
    - learnable mapping      straight-through softmax over encoder outputs
                             (hard one-hot forward, soft softmax backward)
    - differentiable LUTs    multilinear interpolation of a real-valued
                             table over the 6 soft address bits
    - class scores           mean of sigmoid(LUT values) per class group
* ``hard_forward`` — the discrete network the hardware implements; built on
  the L1 pallas kernels (or the jnp oracles, ``use_ref=True``). This is the
  path AOT-lowered to HLO for the rust runtime, and the golden model the
  netlist simulator is checked against.

Model configurations follow the paper (sm-10 / sm-50 / md-360 / lg-2400,
single LUT layer, 5 JSC classes).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import encoding
from .kernels import ref as kref
from .kernels.lut_layer import lut_layer
from .kernels.popcount import popcount
from .kernels.thermometer import thermometer_encode

NUM_CLASSES = 5
NUM_FEATURES = 16
LUT_K = 6


@dataclasses.dataclass(frozen=True)
class DwnConfig:
    """Static hyper-parameters of one DWN variant."""

    name: str
    num_luts: int  # L; must be divisible by NUM_CLASSES
    thermo_bits: int  # T per feature (paper uses 200; we prune unused bits)
    num_features: int = NUM_FEATURES
    num_classes: int = NUM_CLASSES
    lut_k: int = LUT_K

    @property
    def num_bits(self) -> int:
        return self.num_features * self.thermo_bits

    @property
    def pins(self) -> int:
        return self.num_luts * self.lut_k


# The paper's four JSC variants. thermo_bits is reduced from the paper's 200
# to keep single-core CPU training tractable; hardware cost only depends on
# *used* (connected) thresholds, which the generator prunes identically.
CONFIGS = {
    "sm-10": DwnConfig("sm-10", 10, 128),
    "sm-50": DwnConfig("sm-50", 50, 128),
    "md-360": DwnConfig("md-360", 360, 96),
    "lg-2400": DwnConfig("lg-2400", 2400, 64),
}


def init_params(cfg: DwnConfig, key) -> dict:
    """Mapping logits W [pins, num_bits] and real-valued tables theta [L, 64]."""
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (cfg.pins, cfg.num_bits), dtype=jnp.float32) * 0.01
    theta = jax.random.normal(k2, (cfg.num_luts, 1 << cfg.lut_k), dtype=jnp.float32) * 0.1
    return {"w": w, "theta": theta}


def hard_mapping(w, lut_k: int = LUT_K) -> jnp.ndarray:
    """Discrete pin selection: argmax over encoder outputs. [P, N] -> [L, K]."""
    sel = jnp.argmax(w, axis=-1).astype(jnp.int32)
    return sel.reshape(-1, lut_k)


def _st_select(bits, w, tau_map: float):
    """Straight-through mapping: forward uses the argmax bit, backward the
    softmax mixture. bits [B, N], w [P, N] -> [B, P]."""
    p = jax.nn.softmax(w / tau_map, axis=-1)
    soft = bits @ p.T  # [B, P]
    hard = bits[:, jnp.argmax(w, axis=-1)]  # [B, P]
    return soft + jax.lax.stop_gradient(hard - soft)


def _multilinear_lut(theta, s):
    """Multilinear interpolation of tables over soft address bits.

    theta [L, 2^K] real-valued, s [B, L, K] soft bits -> [B, L] real value.
    Pin j is address bit j (LSB-first), matching kref.lut_layer_ref.
    """
    b = s.shape[0]
    t = jnp.broadcast_to(theta[None], (b,) + theta.shape)  # [B, L, 2^K]
    k = s.shape[-1]
    for j in range(k - 1, -1, -1):
        half = t.shape[-1] // 2
        lo = t[..., :half]  # bit j = 0
        hi = t[..., half:]  # bit j = 1
        sj = s[..., j : j + 1]
        t = lo * (1.0 - sj) + hi * sj
    return t[..., 0]


def soft_forward(params, x, thresholds, cfg: DwnConfig, tau_enc=0.03, tau_map=0.3):
    """Differentiable forward -> class logits [B, C]."""
    bits = encoding.encode_soft(x, thresholds, tau_enc)  # [B, N]
    sel_bits = _st_select(bits, params["w"], tau_map)  # [B, P]
    s = sel_bits.reshape(x.shape[0], cfg.num_luts, cfg.lut_k)
    vals = _multilinear_lut(params["theta"], s)  # [B, L]
    outs = jax.nn.sigmoid(4.0 * vals)
    g = cfg.num_luts // cfg.num_classes
    scores = jnp.mean(outs.reshape(-1, cfg.num_classes, g), axis=-1)
    return scores * 12.0  # temperature for cross-entropy


def binarize_tables(theta) -> np.ndarray:
    """Hardware truth tables: entry >= 0 -> 1."""
    return (np.asarray(theta) >= 0.0).astype(np.float32)


def hard_forward(x, thresholds, sel, tables, num_classes=NUM_CLASSES, use_ref=False):
    """Discrete inference (the hardware's function). Returns (scores, pred)."""
    if use_ref:
        return kref.dwn_forward_ref(x, thresholds, sel, tables, num_classes)
    bits = thermometer_encode(x, thresholds)
    outs = lut_layer(bits, sel, tables)
    scores = popcount(outs, num_classes)
    return scores, kref.argmax_ref(scores)


def hard_accuracy(x, y, thresholds, sel, tables, num_classes=NUM_CLASSES, batch=2048):
    """Test-set accuracy of the discrete network (jnp oracle path, batched)."""
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        xb = jnp.asarray(x[i : i + batch])
        _, pred = kref.dwn_forward_ref(xb, thresholds, sel, tables, num_classes)
        correct += int(jnp.sum(pred == jnp.asarray(y[i : i + batch])))
    return correct / n


def used_bits(sel: np.ndarray) -> np.ndarray:
    """Sorted unique encoder-output indices actually connected to the LUT
    layer — the only thresholds that need comparators in hardware."""
    return np.unique(np.asarray(sel).ravel())

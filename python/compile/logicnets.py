"""LogicNets-lite baseline (Umuroglu et al., FPL'20): a sparse, activation-
quantized MLP whose neurons enumerate to LUT truth tables.

Scaled-down but faithful to the idea: each neuron has a fixed random sparse
fan-in of F inputs, activations are quantized to A bits, so a neuron is a
lookup table over F*A input bits — with F*A <= 6 every neuron output bit is
exactly one physical LUT6 (the regime LogicNets targets; larger F*A grows
hardware exponentially, the scalability wall the paper's §II cites).

Training: straight-through quantization, Adam, same synthetic JSC data as
the DWN models. Export: per-neuron truth tables enumerated exhaustively
(2^(F*A) entries) into artifacts/models/logicnets-<name>.json for the rust
hardware generator (rust/src/baselines/logicnets.rs).

Run: python -m compile.logicnets --out ../artifacts
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import data as jsc_data
from . import train as dwn_train

NUM_CLASSES = 5


def quantize_ste(x, bits: float, lo: float, hi: float):
    """Uniform quantization with a straight-through gradient."""
    levels = 2.0**bits - 1.0
    xc = jnp.clip(x, lo, hi)
    q = jnp.round((xc - lo) / (hi - lo) * levels) / levels * (hi - lo) + lo
    return xc + jax.lax.stop_gradient(q - xc)


class LogicNetsConfig:
    def __init__(self, name="jsc-lite", hidden=(32,), fanin=3, abits=2, ibits=2, seed=11):
        assert fanin * abits <= 6, "neuron must fit one LUT6 per output bit"
        self.name = name
        self.hidden = tuple(hidden)
        self.fanin = fanin
        self.abits = abits
        self.ibits = ibits  # input-feature quantization bits
        self.seed = seed

    @property
    def layer_sizes(self):
        return (16,) + self.hidden + (NUM_CLASSES,)


def init(cfg: LogicNetsConfig):
    rng = np.random.default_rng(cfg.seed)
    params = []
    masks = []
    sizes = cfg.layer_sizes
    for li in range(len(sizes) - 1):
        n_in, n_out = sizes[li], sizes[li + 1]
        sel = np.stack([rng.choice(n_in, size=cfg.fanin, replace=False) for _ in range(n_out)])
        w = rng.normal(0, 0.5, size=(n_out, cfg.fanin)).astype(np.float32)
        b = np.zeros(n_out, dtype=np.float32)
        masks.append(sel.astype(np.int32))
        params.append({"w": jnp.asarray(w), "b": jnp.asarray(b)})
    return params, masks


def forward(params, masks, x, cfg: LogicNetsConfig, hard=False):
    """x in [-1,1); activations quantized to abits in [-1,1)."""
    h = quantize_ste(x, cfg.ibits, -1.0, 1.0)
    for li, (p, sel) in enumerate(zip(params, masks)):
        gathered = h[:, sel]  # [B, n_out, fanin]
        z = jnp.sum(gathered * p["w"][None], axis=-1) + p["b"][None]
        if li < len(params) - 1:
            h = jnp.tanh(z)
            h = quantize_ste(h, cfg.abits, -1.0, 1.0)
        else:
            h = z  # final layer: real-valued class scores
    return h


def train(cfg: LogicNetsConfig, xt, yt, xe, ye, steps=500, batch=256, lr=0.01, verbose=True):
    params, masks = init(cfg)
    opt = dwn_train.adam_init(params)
    rng = np.random.default_rng(cfg.seed)

    @jax.jit
    def step_fn(params, opt, xb, yb, cur_lr):
        def loss_fn(p):
            logits = forward(p, masks, xb, cfg)
            return dwn_train.cross_entropy(logits * 4.0, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = dwn_train.adam_step(params, grads, opt, cur_lr)
        return params, opt, loss

    for s in range(steps):
        idx = rng.integers(0, len(xt), size=batch)
        cur_lr = dwn_train.step_lr(lr, s, int(steps * 0.7), 0.1)
        params, opt, loss = step_fn(params, opt, jnp.asarray(xt[idx]), jnp.asarray(yt[idx]), cur_lr)
        if verbose and s % max(1, steps // 4) == 0:
            acc = accuracy(params, masks, xe[:2000], ye[:2000], cfg)
            print(f"[logicnets {cfg.name}] step {s} loss {float(loss):.4f} acc {acc:.4f}", flush=True)
    return params, masks


def accuracy(params, masks, x, y, cfg):
    logits = forward(params, masks, jnp.asarray(x), cfg)
    pred = np.asarray(jnp.argmax(logits, axis=-1))
    return float((pred == y).mean())


# ------------------------------------------------------------------ export
def act_codes(bits: int) -> np.ndarray:
    """The 2^bits quantized activation values in [-1, 1)."""
    levels = 2**bits - 1
    return np.array([-1.0 + 2.0 * i / levels for i in range(levels + 1)], dtype=np.float64)


def enumerate_neuron(w, b, sel_codes, out_codes, is_last):
    """Truth table of one neuron: input = fanin digits (each abits wide),
    output = index into out_codes (or raw quantized score for the last
    layer). Returns int array of length prod(len(sel_codes))."""
    fanin = len(w)
    n_codes = len(sel_codes)
    total = n_codes**fanin
    out = np.zeros(total, dtype=np.int64)
    for addr in range(total):
        a = addr
        z = b
        for j in range(fanin):
            digit = a % n_codes
            a //= n_codes
            z += w[j] * sel_codes[digit]
        if is_last:
            out[addr] = int(np.round(z * 1000))  # milli-units, argmax-safe
        else:
            v = np.tanh(z)
            # nearest quantized activation index
            out[addr] = int(np.argmin(np.abs(out_codes - np.clip(v, -1, 1))))
    return out


def export(cfg: LogicNetsConfig, params, masks, acc, out_dir: str):
    in_codes = act_codes(cfg.ibits)
    hid_codes = act_codes(cfg.abits)
    layers = []
    sizes = cfg.layer_sizes
    for li, (p, sel) in enumerate(zip(params, masks)):
        is_last = li == len(params) - 1
        w = np.asarray(p["w"])
        b = np.asarray(p["b"])
        codes_in = in_codes if li == 0 else hid_codes
        neurons = []
        for n in range(sizes[li + 1]):
            table = enumerate_neuron(w[n], float(b[n]), codes_in, hid_codes, is_last)
            neurons.append({"sel": sel[n].tolist(), "table": table.tolist()})
        layers.append({"is_last": is_last, "neurons": neurons})
    doc = {
        "name": cfg.name,
        "fanin": cfg.fanin,
        "abits": cfg.abits,
        "ibits": cfg.ibits,
        "layer_sizes": list(sizes),
        "acc": acc,
        "layers": layers,
    }
    path = f"{out_dir}/models/logicnets-{cfg.name}.json"
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"[logicnets {cfg.name}] exported {path} (acc {acc:.4f})")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=500)
    args = ap.parse_args()
    xt, yt, xe, ye = jsc_data.load_jsc(40_000, 10_000)
    for cfg in [
        LogicNetsConfig("jsc-s", hidden=(16,), fanin=3, abits=2, ibits=2),
        LogicNetsConfig("jsc-m", hidden=(32, 16), fanin=3, abits=2, ibits=2),
    ]:
        params, masks = train(cfg, xt, yt, xe, ye, steps=args.steps)
        acc = accuracy(params, masks, xe, ye, cfg)
        export(cfg, params, masks, acc, args.out)


if __name__ == "__main__":
    main()

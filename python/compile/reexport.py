"""Re-export HLO + golden artifacts from stored model JSON without retraining.

Useful when only the export format changes (e.g. the print_large_constants
fix): ``python -m compile.reexport --configs md-360,lg-2400``.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from . import aot


def model_from_json(path: str):
    with open(path) as f:
        return json.load(f)


def tables_from_hex(hexes: list[str], lut_k: int) -> np.ndarray:
    n = 1 << lut_k
    out = np.zeros((len(hexes), n), np.float32)
    for l, h in enumerate(hexes):
        mask = int(h, 16)
        for i in range(n):
            out[l, i] = (mask >> i) & 1
    return out


def reexport(out: str, name: str) -> None:
    m = model_from_json(f"{out}/models/{name}.json")
    v = m["variants"]["penft"]
    th_q = (np.array(v["threshold_ints"], dtype=np.float64) / (1 << v["frac_bits"])).astype(
        np.float32
    )
    sel = np.array(v["sel"], dtype=np.int32)
    tables = tables_from_hex(v["tables_hex"], m["lut_k"])
    n = aot.export_hlo(f"{out}/hlo/{name}_penft.hlo.txt", th_q, sel, tables, m["num_classes"])
    print(f"[{name}] re-exported HLO ({n} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="sm-10,sm-50,md-360,lg-2400")
    args = ap.parse_args()
    for name in args.configs.split(","):
        reexport(args.out, name.strip())


if __name__ == "__main__":
    main()

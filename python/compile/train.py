"""DWN training (paper §III): Adam, StepLR-style decay, straight-through
gradients. Self-contained optimizer (optax is not available offline).

The procedure mirrors the paper: features normalised to [-1, 1), distributive
thermometer encoding, gradient-based learning of both the encoder->LUT
mapping and the LUT contents, cross-entropy on the popcount scores.
"""

from __future__ import annotations

import functools
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as jsc_data
from . import encoding, model


# ---------------------------------------------------------------- optimizer
def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def step_lr(base_lr: float, step: int, step_size: int, gamma: float) -> float:
    """StepLR(step_size, gamma) as in the paper (§III)."""
    return base_lr * (gamma ** (step // step_size))


# ---------------------------------------------------------------- training
def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _train_step(params, opt, x, y, thresholds, cfg, lr):
    def loss_fn(p):
        logits = model.soft_forward(p, x, thresholds, cfg)
        return cross_entropy(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt = adam_step(params, grads, opt, lr)
    return params, opt, loss


def evaluate_hard(params, x, y, thresholds, cfg, max_n=6000):
    sel = np.asarray(model.hard_mapping(params["w"]))
    tables = model.binarize_tables(params["theta"])
    n = min(max_n, x.shape[0])
    return model.hard_accuracy(x[:n], y[:n], jnp.asarray(thresholds), jnp.asarray(sel), jnp.asarray(tables), cfg.num_classes)


def train(
    cfg: model.DwnConfig,
    x_train,
    y_train,
    x_test,
    y_test,
    thresholds,
    steps: int = 400,
    batch: int = 128,
    lr: float = 0.01,
    seed: int = 7,
    params: dict | None = None,
    lr_step_size: int | None = None,
    lr_gamma: float = 0.1,
    log_every: int = 100,
    verbose: bool = True,
):
    """Train (or fine-tune, if ``params`` given) a DWN variant.

    Returns (params, history). ``thresholds`` stay fixed during fine-tuning —
    exactly the paper's PEN+FT procedure (quantized thresholds frozen, LUT
    contents + mapping re-trained for a few epochs with Adam/StepLR).
    """
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init_params(cfg, key)
    opt = adam_init(params)
    th = jnp.asarray(thresholds)
    n = x_train.shape[0]
    rng = np.random.default_rng(seed)
    if lr_step_size is None:
        lr_step_size = max(1, int(steps * 0.75))
    hist = []
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        xb = jnp.asarray(x_train[idx])
        yb = jnp.asarray(y_train[idx])
        cur_lr = step_lr(lr, step, lr_step_size, lr_gamma)
        params, opt, loss = _train_step(params, opt, xb, yb, th, cfg, cur_lr)
        if verbose and (step % log_every == 0 or step == steps - 1):
            acc = evaluate_hard(params, x_test, y_test, thresholds, cfg, max_n=3000)
            hist.append({"step": step, "loss": float(loss), "hard_acc": acc, "t": time.time() - t0})
            print(f"[{cfg.name}] step {step:5d} loss {float(loss):.4f} hard-acc {acc:.4f}", flush=True)
    return params, hist

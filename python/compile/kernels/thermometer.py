"""Pallas kernel: thermometer encoding (L1 hot-spot #1).

TPU adaptation of the paper's comparator array (Fig. 3): the F*T comparators
become one broadcast compare of an input tile against the [F, T] threshold
matrix resident in VMEM. BlockSpec tiles the batch dimension; the threshold
matrix (16 x 200 f32 = 12.5 KiB at paper scale) fits VMEM whole, so each
grid step streams one batch tile HBM->VMEM and writes the encoded bits back.

interpret=True everywhere: real-TPU lowering would emit a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _encode_kernel(x_ref, th_ref, out_ref):
    x = x_ref[...]  # [TB, F]
    th = th_ref[...]  # [F, T]
    bits = (x[:, :, None] >= th[None, :, :]).astype(jnp.float32)
    out_ref[...] = bits.reshape(x.shape[0], -1)


def thermometer_encode(x, thresholds, block_b: int = DEFAULT_BLOCK_B):
    """x [B, F] f32, thresholds [F, T] f32 -> bits [B, F*T] f32 in {0,1}.

    B must be a multiple of block_b (callers pad); F, T are static.
    """
    b, f = x.shape
    t = thresholds.shape[1]
    if b % block_b != 0:
        block_b = b  # fall back to a single tile for odd batches
    grid = (b // block_b,)
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct((b, f * t), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, f * t), lambda i: (i, 0)),
        interpret=True,
    )(x, thresholds)

"""Pallas kernel: per-class popcount (L1 hot-spot #3).

The hardware compressor trees (FloPoCo GPCs, paper SIV) reduce each class
group of LUT outputs to a sum; on TPU this is a segment-sum, expressed as a
reshape + axis reduction over the contiguous class groups. Batch-tiled like
the other kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _popcount_kernel(outs_ref, scores_ref, *, num_classes: int):
    outs = outs_ref[...]  # [TB, L]
    tb, l = outs.shape
    g = l // num_classes
    scores_ref[...] = jnp.sum(outs.reshape(tb, num_classes, g), axis=-1).astype(jnp.int32)


def popcount(outs, num_classes: int, block_b: int = DEFAULT_BLOCK_B):
    """outs [B, L] f32{0,1} with L = C*G -> scores [B, C] i32."""
    b, l = outs.shape
    if l % num_classes != 0:
        raise ValueError(f"L={l} not divisible by num_classes={num_classes}")
    if b % block_b != 0:
        block_b = b
    grid = (b // block_b,)
    import functools

    return pl.pallas_call(
        functools.partial(_popcount_kernel, num_classes=num_classes),
        out_shape=jax.ShapeDtypeStruct((b, num_classes), jnp.int32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, l), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, num_classes), lambda i: (i, 0)),
        interpret=True,
    )(outs)

"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Shapes (contract shared with the pallas kernels and the rust netlist):
  x          [B, F]      float32 in [-1, 1)
  thresholds [F, T]      float32, sorted ascending per feature
  bits       [B, F*T]    float32 in {0, 1}
  sel        [L, K]      int32 indices into the F*T bit vector (K = LUT fan-in)
  tables     [L, 2**K]   float32 in {0, 1} (binarised truth tables)
  scores     [B, C]      int32 per-class popcount
  pred       [B]         int32 argmax (ties -> lower class index)
"""

from __future__ import annotations

import jax.numpy as jnp

POWS = [1, 2, 4, 8, 16, 32, 64, 128]


def encode_ref(x, thresholds):
    """Thermometer encode: bit (f,t) = x[:,f] >= thresholds[f,t]."""
    b = (x[:, :, None] >= thresholds[None, :, :]).astype(jnp.float32)
    return b.reshape(x.shape[0], -1)


def lut_layer_ref(bits, sel, tables):
    """Evaluate L LUTs: out[b,l] = tables[l, addr(b,l)].

    addr(b,l) = sum_j bits[b, sel[l,j]] << j  (pin j is address bit j).
    """
    k = sel.shape[1]
    gathered = bits[:, sel]  # [B, L, K]
    pows = jnp.asarray(POWS[:k], dtype=jnp.int32)
    addr = jnp.sum(gathered.astype(jnp.int32) * pows[None, None, :], axis=-1)  # [B, L]
    return _gather_tables(tables, addr)


def _gather_tables(tables, addr):
    # tables [L, 2^K], addr [B, L] -> out [B, L]
    return jnp.take_along_axis(
        jnp.broadcast_to(tables[None], (addr.shape[0],) + tables.shape),
        addr[:, :, None],
        axis=2,
    )[:, :, 0]


def popcount_ref(outs, num_classes):
    """Per-class popcount: outs [B, L] with L = C*G contiguous groups -> [B, C]."""
    b, l = outs.shape
    g = l // num_classes
    return jnp.sum(outs.reshape(b, num_classes, g), axis=-1).astype(jnp.int32)


def argmax_ref(scores):
    """Argmax over classes; jnp.argmax picks the first (lowest index) maximum,
    matching the paper's tie rule (Fig. 4: ties -> lower class index)."""
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def dwn_forward_ref(x, thresholds, sel, tables, num_classes):
    """Full hard inference path: encode -> LUT layer -> popcount -> argmax."""
    bits = encode_ref(x, thresholds)
    outs = lut_layer_ref(bits, sel, tables)
    scores = popcount_ref(outs, num_classes)
    return scores, argmax_ref(scores)

"""Pallas kernel: DWN LUT-layer evaluation (L1 hot-spot #2).

FPGA->TPU mapping: each hardware LUT6 (Fig. 1) is a 64-entry truth table.
On TPU we keep all L tables ([L, 64] f32; 600 KiB for lg-2400) and the
selection matrix ([L, 6] i32) resident in VMEM and tile the batch. The
address computation (6 gathered bits -> integer 0..63) is a tiny dense
matvec against the powers-of-two vector; the table lookup is a row-wise
gather, which interpret-mode lowers to plain HLO gather ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _lut_kernel(bits_ref, sel_ref, tab_ref, out_ref):
    bits = bits_ref[...]  # [TB, N]
    sel = sel_ref[...]  # [L, K]
    tables = tab_ref[...]  # [L, 2^K]
    k = sel.shape[1]
    gathered = bits[:, sel]  # [TB, L, K]
    pows = (2 ** jnp.arange(k, dtype=jnp.int32))[None, None, :]
    addr = jnp.sum(gathered.astype(jnp.int32) * pows, axis=-1)  # [TB, L]
    tb = jnp.broadcast_to(tables[None], (bits.shape[0],) + tables.shape)
    out_ref[...] = jnp.take_along_axis(tb, addr[:, :, None], axis=2)[:, :, 0]


def lut_layer(bits, sel, tables, block_b: int = DEFAULT_BLOCK_B):
    """bits [B, N] f32{0,1}, sel [L, K] i32, tables [L, 2^K] f32 -> [B, L] f32."""
    b, n = bits.shape
    l, k = sel.shape
    if b % block_b != 0:
        block_b = b
    grid = (b // block_b,)
    return pl.pallas_call(
        _lut_kernel,
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, tables.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, l), lambda i: (i, 0)),
        interpret=True,
    )(bits, sel, tables)

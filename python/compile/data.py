"""Synthetic Jet Substructure Classification (JSC) dataset.

The paper evaluates on the OpenML hls4ml JSC dataset (16 high-level jet
features, 5 jet classes: g, q, W, Z, t). That dataset is not available in
this environment, so we generate a statistically similar surrogate:

* 16 features with heterogeneous, heavy-tailed marginals (multiplicity-like
  counts, energy-correlation-like positives, mass-like mixtures) so that
  *distributive* (quantile) thermometer encoding genuinely beats uniform
  encoding — the property paper Fig. 2 illustrates.
* 5 classes drawn from a shared 3-factor latent space with class-dependent
  loadings; class overlap is tuned so that model capacity maps to the
  paper's accuracy band (~71% for sm-10 up to ~76-78% for lg-2400).
* Features are normalised to [-1, 1) with 0.5/99.5 percentile clipping,
  exactly as the paper normalises before encoding.

The generator is a fixed-seed splitmix64 PRNG and is mirrored bit-for-bit in
``rust/src/data/synth.rs`` so the rust side can regenerate the same dataset
without artifacts (cross-checked by test_data_rust_parity).
"""

from __future__ import annotations

import numpy as np

NUM_FEATURES = 16
NUM_CLASSES = 5
CLASS_NAMES = ["g", "q", "W", "Z", "t"]

_MASK = (1 << 64) - 1


class SplitMix64:
    """Deterministic, language-portable PRNG (same constants as rust mirror)."""

    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        return (z ^ (z >> 31)) & _MASK

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53-bit resolution."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_normal(self) -> float:
        """Box-Muller, consuming exactly two uniforms (portable)."""
        u1 = self.next_f64()
        u2 = self.next_f64()
        if u1 < 1e-300:
            u1 = 1e-300
        import math

        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def _class_params(rng: SplitMix64):
    """Class-conditional latent loadings + feature maps, from the PRNG stream."""
    # 3 latent factors; per class a mean vector in latent space. Classes are
    # well separated except W (2) and Z (3), which overlap heavily — mirroring
    # the real JSC task where W/Z discrimination is the hard margin that only
    # larger models resolve (keeps the paper's tight 71-76% accuracy band).
    lat_means = np.empty((NUM_CLASSES, 3))
    for c in range(NUM_CLASSES):
        for k in range(3):
            lat_means[c, k] = rng.next_normal() * 2.2
    for k in range(3):
        lat_means[3, k] = lat_means[2, k] + 0.55 * rng.next_normal()
    # Feature loadings [F, 3] and per-feature noise scales / shapes.
    load = np.empty((NUM_FEATURES, 3))
    for f in range(NUM_FEATURES):
        for k in range(3):
            load[f, k] = rng.next_normal()
    noise = np.empty(NUM_FEATURES)
    for f in range(NUM_FEATURES):
        noise[f] = 0.5 + 0.7 * rng.next_f64()
    # Feature "style": 0 = gaussian, 1 = lognormal-ish (energy), 2 = count-like.
    style = np.empty(NUM_FEATURES, dtype=np.int64)
    for f in range(NUM_FEATURES):
        style[f] = rng.next_u64() % 3
    return lat_means, load, noise, style


def generate_raw(num_samples: int, seed: int = 0xD5C0DE):
    """Raw (unnormalised) features + labels. Fully deterministic in `seed`."""
    rng = SplitMix64(seed)
    lat_means, load, noise, style = _class_params(rng)
    x = np.empty((num_samples, NUM_FEATURES), dtype=np.float64)
    y = np.empty(num_samples, dtype=np.int64)
    for i in range(num_samples):
        c = rng.next_u64() % NUM_CLASSES
        y[i] = c
        z = np.array([lat_means[c, k] + rng.next_normal() for k in range(3)])
        for f in range(NUM_FEATURES):
            v = float(load[f] @ z) + noise[f] * rng.next_normal()
            s = style[f]
            if s == 1:  # heavy right tail, strictly positive (energy-correlation like)
                v = np.expm1(0.55 * v) if v > 0 else -np.expm1(-0.25 * v)
            elif s == 2:  # count-like: coarse discretisation
                v = np.floor(v * 2.0) / 2.0
            x[i, f] = v
    return x, y


def normalize(x: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Affine map of [lo, hi] -> [-1, 1), clipped. lo/hi: per-feature [F]."""
    span = np.maximum(hi - lo, 1e-9)
    z = 2.0 * (x - lo) / span - 1.0
    return np.clip(z, -1.0, np.nextafter(1.0, 0.0)).astype(np.float32)


def norm_bounds(train_x: np.ndarray):
    """0.5 / 99.5 percentile clipping bounds from the training split."""
    lo = np.percentile(train_x, 0.5, axis=0)
    hi = np.percentile(train_x, 99.5, axis=0)
    return lo, hi


def load_jsc(num_train: int = 50_000, num_test: int = 10_000, seed: int = 0xD5C0DE):
    """Returns (x_train, y_train, x_test, y_test) with x normalised to [-1, 1)."""
    x, y = generate_raw(num_train + num_test, seed)
    xt, yt = x[:num_train], y[:num_train]
    xe, ye = x[num_train:], y[num_train:]
    lo, hi = norm_bounds(xt)
    return normalize(xt, lo, hi), yt, normalize(xe, lo, hi), ye


def to_csv(path: str, x: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as f:
        cols = ",".join(f"f{i}" for i in range(x.shape[1]))
        f.write(f"{cols},label\n")
        for row, lab in zip(x, y):
            f.write(",".join(f"{v:.7f}" for v in row) + f",{int(lab)}\n")

"""AOT driver: train DWN variants, quantize, fine-tune, and export artifacts.

Outputs (consumed by the rust layer — python never runs at request time):

  artifacts/data/jsc_{train,test}.csv      synthetic JSC dataset
  artifacts/models/<cfg>.json              trained model: thresholds, mapping,
                                           truth tables, TEN/PEN/PEN+FT
                                           variants, bit-width sweep (Fig 5)
  artifacts/hlo/<cfg>_penft.hlo.txt        hard-inference graph as HLO TEXT
                                           (jax>=0.5 serialized protos use
                                           64-bit ids that xla_extension
                                           0.5.1 rejects; text round-trips)
  artifacts/golden/<cfg>_<variant>.csv     golden vectors for netlist verify
  artifacts/manifest.json                  index of everything above

Run via ``make artifacts`` (no-op when up to date). QUICK=1 trains tiny
models for CI-style smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as jsc_data
from . import encoding, model, quantize, train

HLO_BATCH = 128


# ------------------------------------------------------------------ helpers
def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see /opt/xla-example/gen_hlo.py)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def tables_to_hex(tables: np.ndarray) -> list[str]:
    """[L, 64] {0,1} -> 16-hex-digit strings, bit i of the mask = entry i."""
    out = []
    for row in np.asarray(tables).astype(np.int64):
        mask = 0
        for i, v in enumerate(row):
            if v:
                mask |= 1 << i
        out.append(f"{mask:016x}")
    return out


def export_hlo(path, thresholds, sel, tables, num_classes):
    """Lower the hard inference path (pallas kernels) to HLO text."""

    th = jnp.asarray(thresholds)
    se = jnp.asarray(np.asarray(sel, dtype=np.int32))
    tb = jnp.asarray(np.asarray(tables, dtype=np.float32))

    def infer(x):
        scores, pred = model.hard_forward(x, th, se, tb, num_classes)
        return scores, pred

    spec = jax.ShapeDtypeStruct((HLO_BATCH, th.shape[0]), jnp.float32)
    lowered = jax.jit(infer).lower(spec)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def export_golden_pen(path, x_test, y_test, thresholds_q, frac_bits, sel, tables, num_classes, n=512):
    """Golden vectors for PEN hardware: integer inputs + expected scores/pred."""
    x_q = encoding.quantize_inputs(x_test[:n], frac_bits)
    xi = encoding.input_ints(x_test[:n], frac_bits)
    scores, pred = model.hard_forward(
        jnp.asarray(x_q), jnp.asarray(thresholds_q), jnp.asarray(sel), jnp.asarray(tables), num_classes, use_ref=True
    )
    scores = np.asarray(scores)
    pred = np.asarray(pred)
    with open(path, "w") as f:
        f.write(f"# frac_bits={frac_bits} format=pen\n")
        cols = ",".join(f"x{i}" for i in range(xi.shape[1]))
        scols = ",".join(f"s{i}" for i in range(num_classes))
        f.write(f"{cols},{scols},pred,label\n")
        for i in range(xi.shape[0]):
            f.write(
                ",".join(str(v) for v in xi[i])
                + ","
                + ",".join(str(v) for v in scores[i])
                + f",{pred[i]},{int(y_test[i])}\n"
            )


def export_golden_ten(path, x_test, y_test, thresholds, sel, tables, num_classes, n=512):
    """Golden vectors for TEN hardware: used-bit hex strings + scores/pred."""
    used = model.used_bits(sel)
    bits = np.asarray(encoding.encode(jnp.asarray(x_test[:n]), jnp.asarray(thresholds)))
    scores, pred = model.hard_forward(
        jnp.asarray(x_test[:n]), jnp.asarray(thresholds), jnp.asarray(sel), jnp.asarray(tables), num_classes, use_ref=True
    )
    scores = np.asarray(scores)
    pred = np.asarray(pred)
    with open(path, "w") as f:
        f.write(f"# format=ten used_bits={len(used)}\n")
        scols = ",".join(f"s{i}" for i in range(num_classes))
        f.write(f"bits_hex,{scols},pred,label\n")
        for i in range(n):
            ub = bits[i, used].astype(np.int64)
            mask = 0
            for j, v in enumerate(ub):
                if v:
                    mask |= 1 << j
            hexlen = (len(used) + 3) // 4
            f.write(f"{mask:0{hexlen}x}," + ",".join(str(v) for v in scores[i]) + f",{pred[i]},{int(y_test[i])}\n")


# ------------------------------------------------------------------- driver
def budget(cfg_name: str, quick: bool):
    """(base_steps, batch, ft_steps, sweep_bws)."""
    if quick:
        return 60, 64, 20, [6, 8]
    return {
        "sm-10": (700, 256, 150, [4, 5, 6, 7, 8, 9, 10]),
        "sm-50": (700, 256, 150, [4, 5, 6, 7, 8, 9, 10]),
        "md-360": (500, 192, 120, [5, 6, 7, 8, 9, 10]),
        "lg-2400": (300, 96, 90, [6, 7, 8, 9, 10]),
    }[cfg_name]


def run_config(cfg, xt, yt, xe, ye, out, quick):
    steps, batch, ft_steps, sweep_bws = budget(cfg.name, quick)
    th = encoding.distributive_thresholds(xt, cfg.thermo_bits)
    th_uni = encoding.uniform_thresholds(cfg.num_features, cfg.thermo_bits)

    t0 = time.time()
    # Small models are cheap but land in bad local optima more often (the
    # mapping is a hard discrete problem at 60 pins); use random restarts.
    restarts = 3 if cfg.num_luts <= 50 and not quick else 1
    params, hist, base_acc = None, None, -1.0
    for r in range(restarts):
        p_r, h_r = train.train(
            cfg, xt, yt, xe, ye, th, steps=steps, batch=batch,
            seed=7 + 11 * r, log_every=max(1, steps // 4),
        )
        acc_r = train.evaluate_hard(p_r, xe, ye, th, cfg, max_n=len(xe))
        print(f"[{cfg.name}] restart {r}: acc={acc_r:.4f}")
        if acc_r > base_acc:
            params, hist, base_acc = p_r, h_r, acc_r
    print(f"[{cfg.name}] TEN baseline acc={base_acc:.4f} ({time.time()-t0:.0f}s)")

    sel = np.asarray(model.hard_mapping(params["w"]))
    tables = model.binarize_tables(params["theta"])

    # --- PTQ (DWN-PEN): smallest n meeting baseline without fine-tuning.
    pen_bw, ptq_accs = quantize.ptq_sweep(params, th, xe, ye, cfg, base_acc, tol=0.004)
    pen_acc = ptq_accs[pen_bw]
    print(f"[{cfg.name}] PEN: frac_bits={pen_bw} acc={pen_acc:.4f}")

    # --- bit-width sweep with fine-tuning (Fig 5 + PEN+FT selection).
    sweep = []
    ft_models = {}
    for bw in sweep_bws:
        acc_pen = ptq_accs.get(bw)
        if acc_pen is None:
            acc_pen = quantize.quantized_accuracy(params, th, bw, xe, ye, cfg)
        ftp, th_q, acc_ft = quantize.fine_tune(
            params, th, bw, cfg, xt, yt, xe, ye, steps=ft_steps
        )
        sweep.append({"frac_bits": bw, "acc_pen": float(acc_pen), "acc_penft": float(acc_ft)})
        ft_models[bw] = (ftp, th_q, acc_ft)
        print(f"[{cfg.name}] bw={bw}: PEN {acc_pen:.4f} -> PEN+FT {acc_ft:.4f}")

    # PEN+FT bit-width: smallest bw whose fine-tuned accuracy recovers baseline.
    penft_bw = None
    for bw in sorted(b["frac_bits"] for b in sweep):
        acc = next(s["acc_penft"] for s in sweep if s["frac_bits"] == bw)
        if acc >= base_acc - 0.004:
            penft_bw = bw
            break
    if penft_bw is None:
        penft_bw = max(s["frac_bits"] for s in sweep)
    ftp, th_q_ft, penft_acc = ft_models[penft_bw]
    sel_ft = np.asarray(model.hard_mapping(ftp["w"]))
    tables_ft = model.binarize_tables(ftp["theta"])
    print(f"[{cfg.name}] PEN+FT: frac_bits={penft_bw} acc={penft_acc:.4f}")

    th_q_pen = encoding.quantize_thresholds(th, pen_bw)

    # ----------------------------------------------------------- exports
    mj = {
        "name": cfg.name,
        "num_luts": cfg.num_luts,
        "thermo_bits": cfg.thermo_bits,
        "num_features": cfg.num_features,
        "num_classes": cfg.num_classes,
        "lut_k": cfg.lut_k,
        "sel": sel.tolist(),
        "tables_hex": tables_to_hex(tables),
        "thresholds": np.asarray(th).tolist(),
        "uniform_thresholds": np.asarray(th_uni).tolist(),
        "history": hist,
        "variants": {
            "ten": {"acc": float(base_acc)},
            "pen": {
                "frac_bits": int(pen_bw),
                "acc": float(pen_acc),
                "threshold_ints": encoding.threshold_ints(th_q_pen, pen_bw).tolist(),
            },
            "penft": {
                "frac_bits": int(penft_bw),
                "acc": float(penft_acc),
                "threshold_ints": encoding.threshold_ints(th_q_ft, penft_bw).tolist(),
                "sel": sel_ft.tolist(),
                "tables_hex": tables_to_hex(tables_ft),
            },
        },
        "bw_sweep": sweep,
    }
    with open(f"{out}/models/{cfg.name}.json", "w") as f:
        json.dump(mj, f)

    n_hlo = export_hlo(
        f"{out}/hlo/{cfg.name}_penft.hlo.txt",
        encoding.quantize_thresholds(th, penft_bw),
        sel_ft,
        tables_ft,
        cfg.num_classes,
    )
    print(f"[{cfg.name}] HLO exported ({n_hlo} chars)")

    export_golden_pen(
        f"{out}/golden/{cfg.name}_penft.csv", xe, ye, th_q_ft, penft_bw, sel_ft, tables_ft, cfg.num_classes
    )
    export_golden_pen(
        f"{out}/golden/{cfg.name}_pen.csv", xe, ye, th_q_pen, pen_bw, sel, tables, cfg.num_classes
    )
    export_golden_ten(f"{out}/golden/{cfg.name}_ten.csv", xe, ye, th, sel, tables, cfg.num_classes)
    return mj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="sm-10,sm-50,md-360,lg-2400")
    ap.add_argument("--quick", action="store_true", default=os.environ.get("QUICK") == "1")
    args = ap.parse_args()

    out = args.out
    for d in ("", "/data", "/models", "/hlo", "/golden", "/results"):
        os.makedirs(out + d, exist_ok=True)

    n_train, n_test = (6000, 2000) if args.quick else (40_000, 10_000)
    xt, yt, xe, ye = jsc_data.load_jsc(n_train, n_test)
    jsc_data.to_csv(f"{out}/data/jsc_train.csv", xt, yt)
    jsc_data.to_csv(f"{out}/data/jsc_test.csv", xe, ye)
    print(f"dataset: train={len(xt)} test={len(xe)}")

    # Merge into an existing manifest so configs can be (re)trained
    # independently without clobbering the rest.
    manifest = {"configs": [], "quick": args.quick, "hlo_batch": HLO_BATCH}
    mpath = f"{out}/manifest.json"
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
        manifest["quick"] = args.quick
    for name in args.configs.split(","):
        cfg = model.CONFIGS[name.strip()]
        mj = run_config(cfg, xt, yt, xe, ye, out, args.quick)
        entry = {
            "name": cfg.name,
            "model": f"models/{cfg.name}.json",
            "hlo_penft": f"hlo/{cfg.name}_penft.hlo.txt",
            "acc_ten": mj["variants"]["ten"]["acc"],
            "acc_penft": mj["variants"]["penft"]["acc"],
            "penft_bits": mj["variants"]["penft"]["frac_bits"],
        }
        manifest["configs"] = [c for c in manifest["configs"] if c["name"] != cfg.name] + [entry]
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
    print("AOT export complete")


if __name__ == "__main__":
    main()
